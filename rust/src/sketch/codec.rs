//! Binary wire format for sketches, peer states, and exchange frames.
//!
//! A real P2P deployment ships the gossip state over the network; this
//! codec defines those frames (and gives the simulator exact per-message
//! byte accounting, reported in `RoundStats`). Hand-rolled little-endian
//! layout (serde is unavailable offline — DESIGN.md §6):
//!
//! ```text
//! magic "UDDS" | version u8 | alpha0 f64 | collapses u32 | max_buckets u64
//! zero_weight f64
//! pos_len u64 | (index i64, count f64) * pos_len
//! neg_len u64 | (index i64, count f64) * neg_len
//! ```
//!
//! Peer-state frames append `id u64 | n_tilde f64 | q_tilde f64`.
//!
//! The transport layer ([`crate::service::transport`]) wraps peer states
//! in **exchange frames** — the messages of the atomic push–pull
//! protocol:
//!
//! ```text
//! magic "UDDX" | version u8 | kind u8 | generation u64 | trace_id u64 | payload
//! ```
//!
//! where `kind` selects [`ExchangeKind`] and the payload is a peer-state
//! frame (`Push`/`Reply`), a **delta** against a cached baseline
//! (`DeltaPush`/`DeltaReply` — see below), or a one-byte [`RejectReason`]
//! (`Reject`). Every decoder rejects bad magic, unknown versions/kinds,
//! truncation at any offset, and length fields larger than the remaining
//! buffer (so a hostile frame can never trigger a huge allocation).
//! `docs/PROTOCOL.md` is the normative spec of the whole exchange
//! protocol; CI greps this file against its frame-kind table.
//!
//! `trace_id` (version 2) is the cross-node exchange-tracing correlator:
//! the initiator stamps every frame of one logical exchange with one
//! nonzero id and the server **echoes it** in the reply or reject, so
//! the two nodes' span records join into a single causal timeline with
//! no clock agreement. A zero id means "untraced". Version-1 frames
//! (14-byte header, no trace field) still decode with an implied id of
//! 0, so a mixed fleet keeps exchanging during a rolling upgrade; v1
//! *decoders* reject v2 frames as `BadVersion`, which cancels the
//! exchange (§7.2) but corrupts nothing.
//!
//! # Delta frames
//!
//! A completed push–pull leaves **both** partners holding the identical
//! averaged state, so consecutive exchanges between the same pair can
//! ship only what changed since that shared state — the *baseline*. A
//! delta frame carries the sender's scalars in full plus `(index,
//! counter)` **set** operations against the baseline's bucket stores
//! (`counter = 0` removes a bucket); set — not add — semantics keep the
//! reconstruction bit-for-bit exact under floating-point counters.
//! Near convergence almost no buckets change, so a delta frame is a few
//! dozen bytes where a full frame is ~16 KiB at m = 1024.
//!
//! Correct application needs both sides to agree on the baseline
//! *exactly*, so the frame names it by a 64-bit FNV-1a fingerprint of
//! its canonical peer-state encoding ([`peer_state_fingerprint`]); a
//! receiver whose cached baseline is missing or fingerprint-mismatched
//! (or, with baseline carry disabled, from another restart generation)
//! answers [`RejectReason::BaselineMismatch`] and the sender falls
//! back to a full frame. The fingerprint authenticates the baseline
//! bit-for-bit on its own, which is what lets the transport's
//! **baseline-carry** rule (`docs/PROTOCOL.md` §10) compose deltas
//! across restart generations: a reseeded state is just another state
//! to diff against the last mutually-held one. Collapse depth may have
//! advanced since the baseline was cached; the frame carries the
//! sender's current depth and both sides align their baseline copy to
//! it (deterministically) before diffing/applying, so lineage stays
//! exact.
//!
//! The constants here are normative together with `docs/PROTOCOL.md`:
//! the `spec-sync` rule of `dudd-analyze` (see `docs/ANALYSIS.md`)
//! parses the enum discriminants, the `code()`/`from_code()`
//! bijections, and [`VERSION`] against the spec tables in CI, both
//! directions.

use super::{SketchError, Store, UddSketch};
use crate::gossip::PeerState;
// The member table is a payload type exactly like `PeerState` above: the
// codec owns the bytes, the owning subsystem owns the semantics. The
// import runs "upward" into `service` because the ISSUE places the
// membership data model with its runtime (service/membership.rs) and the
// frame catalogue here — one crate, so no cycle is possible.
use crate::service::membership::{MemberEntry, MemberStatus, MemberTable};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr};

const MAGIC: &[u8; 4] = b"UDDS";
const EXCHANGE_MAGIC: &[u8; 4] = b"UDDX";
/// Exchange-protocol version (the `version` byte of every `UDDX`
/// frame). Version 2 added the `trace_id` field to the header;
/// `decode_exchange` still accepts version-1 frames (trace id 0).
/// Normative together with `docs/PROTOCOL.md` (spec-sync checks both).
const VERSION: u8 = 2;
/// The pre-tracing exchange header (no `trace_id`): still decoded, so a
/// v2 node keeps serving v1 peers mid-rolling-upgrade.
const LEGACY_VERSION: u8 = 1;
/// Sketch-payload (`UDDS`) format version — independent of the exchange
/// protocol version: the embedded sketch bytes did not change in v2.
const SKETCH_VERSION: u8 = 1;
/// Byte length of a version-2 exchange header
/// (`magic 4 | version 1 | kind 1 | generation 8 | trace_id 8`).
const EXCHANGE_HEADER_BYTES: usize = 22;
/// Byte length of a version-1 exchange header (no trace id).
const LEGACY_HEADER_BYTES: usize = 14;

/// Encoding/decoding errors.
///
/// (`Display` is hand-written — thiserror is unavailable offline,
/// DESIGN.md §6.)
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    /// Frame too short or structurally invalid.
    Truncated(usize),
    /// Bad magic bytes.
    BadMagic,
    /// Unsupported version byte.
    BadVersion(u8),
    /// Unknown exchange-frame kind byte.
    BadKind(u8),
    /// Decoded parameters failed sketch validation.
    BadParams(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated(pos) => write!(f, "truncated frame at byte {pos}"),
            CodecError::BadMagic => write!(f, "bad magic (not a DUDDSketch frame)"),
            CodecError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            CodecError::BadKind(k) => write!(f, "unknown exchange frame kind {k}"),
            CodecError::BadParams(msg) => write!(f, "invalid sketch parameters: {msg}"),
        }
    }
}

impl std::error::Error for CodecError {}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.pos + n > self.buf.len() {
            return Err(CodecError::Truncated(self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64, CodecError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// A length field for `width`-byte records: rejected when the claimed
    /// count cannot fit in the remaining buffer, so hostile frames are
    /// refused *before* any allocation sized from the wire.
    fn len_field(&mut self, width: usize) -> Result<usize, CodecError> {
        let pos = self.pos;
        let n = self.u64()?;
        if n > (self.remaining() / width) as u64 {
            return Err(CodecError::Truncated(pos));
        }
        Ok(n as usize)
    }
}

fn encode_sketch_into<S: Store>(s: &UddSketch<S>, out: &mut Vec<u8>) {
    out.extend_from_slice(MAGIC);
    out.push(SKETCH_VERSION);
    out.extend_from_slice(&s.mapping().alpha0().to_le_bytes());
    out.extend_from_slice(&s.mapping().collapses().to_le_bytes());
    out.extend_from_slice(&(s.max_buckets() as u64).to_le_bytes());
    out.extend_from_slice(&s.zero_weight().to_le_bytes());
    for store in [s.positive_store(), s.negative_store()] {
        let entries = store.entries();
        out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
        for (i, c) in entries {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
}

fn decode_sketch_from<S: Store>(
    r: &mut Reader<'_>,
) -> Result<UddSketch<S>, CodecError> {
    if r.take(4)? != MAGIC {
        return Err(CodecError::BadMagic);
    }
    let version = r.u8()?;
    if version != SKETCH_VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let alpha0 = r.f64()?;
    let collapses = r.u32()?;
    let max_buckets = r.u64()? as usize;
    let zero_weight = r.f64()?;
    let mut sketch: UddSketch<S> = UddSketch::new(alpha0, max_buckets)
        .map_err(|e: SketchError| CodecError::BadParams(e.to_string()))?;
    sketch.align_to_collapses(collapses);
    let pos_len = r.len_field(16)?;
    let mut pos = Vec::with_capacity(pos_len);
    for _ in 0..pos_len {
        pos.push((r.i64()?, r.f64()?));
    }
    let neg_len = r.len_field(16)?;
    let mut neg = Vec::with_capacity(neg_len);
    for _ in 0..neg_len {
        neg.push((r.i64()?, r.f64()?));
    }
    sketch.load_raw(zero_weight, &pos, &neg);
    Ok(sketch)
}

/// Encode a sketch to its wire frame.
pub fn encode_sketch<S: Store>(s: &UddSketch<S>) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + 16 * s.bucket_count());
    encode_sketch_into(s, &mut out);
    out
}

/// Decode a sketch frame.
pub fn decode_sketch<S: Store>(buf: &[u8]) -> Result<UddSketch<S>, CodecError> {
    decode_sketch_from(&mut Reader::new(buf))
}

fn encode_peer_state_into(s: &PeerState, out: &mut Vec<u8>) {
    encode_sketch_into(&s.sketch, out);
    out.extend_from_slice(&(s.id as u64).to_le_bytes());
    out.extend_from_slice(&s.n_tilde.to_le_bytes());
    out.extend_from_slice(&s.q_tilde.to_le_bytes());
}

fn decode_peer_state_from(r: &mut Reader<'_>) -> Result<PeerState, CodecError> {
    let sketch = decode_sketch_from(r)?;
    let id = r.u64()? as usize;
    let n_tilde = r.f64()?;
    let q_tilde = r.f64()?;
    Ok(PeerState {
        id,
        sketch,
        n_tilde,
        q_tilde,
    })
}

/// Encode a full peer state (gossip message payload).
pub fn encode_peer_state(s: &PeerState) -> Vec<u8> {
    let mut out = Vec::with_capacity(peer_state_wire_size(s));
    encode_peer_state_into(s, &mut out);
    out
}

/// Decode a peer-state frame.
pub fn decode_peer_state(buf: &[u8]) -> Result<PeerState, CodecError> {
    decode_peer_state_from(&mut Reader::new(buf))
}

/// Message kinds of the push–pull exchange protocol (the `kind` byte of
/// the frame header). The numeric values are normative (wire bytes);
/// `docs/PROTOCOL.md` carries the same table and CI checks they agree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeKind {
    /// Initiator → partner: the initiator's framed pre-round state.
    Push = 1,
    /// Partner → initiator: the averaged state both sides adopt.
    Reply = 2,
    /// Partner → initiator: exchange refused; both sides keep their
    /// pre-round state (§7.2 cancelled exchange).
    Reject = 3,
    /// Initiator → partner: the initiator's pre-round state as set-ops
    /// against the pair's cached baseline (see the module docs).
    DeltaPush = 4,
    /// Partner → initiator: the averaged state as set-ops against the
    /// same baseline the push named.
    DeltaReply = 5,
    /// Either direction: the sender's membership table (anti-entropy
    /// push of the membership plane, `docs/PROTOCOL.md` §9).
    MembershipPush = 6,
    /// Server → initiator (or seed → joiner): the server's merged
    /// membership table.
    MembershipReply = 7,
    /// Joiner → seed: the `dudd-join` handshake — assign this listen
    /// address a stable member id and answer with the full table.
    JoinRequest = 8,
}

/// Why a partner refused an inbound exchange.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The partner is mid-exchange or mid-round; retry next round.
    Busy,
    /// The push carried an older restart generation than the partner's
    /// (the frame's `generation` field reports the partner's).
    StaleGeneration,
    /// The sketches' α₀ lineages differ; these peers can never merge.
    Lineage,
    /// The push frame failed to decode.
    Malformed,
    /// A delta push named a baseline the partner does not hold
    /// (missing, fingerprint-mismatched, or — with baseline carry off —
    /// from another generation); the sender retries with a full frame.
    BaselineMismatch,
    /// A membership or join frame reached a node whose membership plane
    /// is not enabled (static address-book fleet); the sender must not
    /// retry.
    NoMembership,
}

impl RejectReason {
    fn code(self) -> u8 {
        match self {
            RejectReason::Busy => 1,
            RejectReason::StaleGeneration => 2,
            RejectReason::Lineage => 3,
            RejectReason::Malformed => 4,
            RejectReason::BaselineMismatch => 5,
            RejectReason::NoMembership => 6,
        }
    }

    fn from_code(code: u8) -> Result<Self, CodecError> {
        Ok(match code {
            1 => RejectReason::Busy,
            2 => RejectReason::StaleGeneration,
            3 => RejectReason::Lineage,
            4 => RejectReason::Malformed,
            5 => RejectReason::BaselineMismatch,
            6 => RejectReason::NoMembership,
            other => {
                return Err(CodecError::BadParams(format!(
                    "unknown reject reason {other}"
                )))
            }
        })
    }
}

/// A decoded exchange frame (see the module docs for the layout).
#[derive(Debug, Clone)]
pub enum ExchangeFrame {
    /// The initiator's framed state at its restart generation.
    Push {
        /// Initiator's restart generation.
        generation: u64,
        /// Initiator's pre-round state.
        state: PeerState,
    },
    /// The averaged state (carrying the initiator's id) both sides adopt.
    Reply {
        /// The serving node's restart generation (equals the push's after
        /// a successful exchange).
        generation: u64,
        /// The averaged state.
        state: PeerState,
    },
    /// Exchange refused; both sides keep their pre-round state.
    Reject {
        /// The serving node's generation (meaningful for
        /// [`RejectReason::StaleGeneration`]; 0 otherwise).
        generation: u64,
        /// Why the exchange was refused.
        reason: RejectReason,
    },
    /// The initiator's pre-round state as a delta against the pair's
    /// cached baseline.
    DeltaPush {
        /// Initiator's restart generation.
        generation: u64,
        /// The delta payload.
        delta: DeltaPayload,
    },
    /// The averaged state as a delta against the same baseline.
    DeltaReply {
        /// The serving node's restart generation (equals the push's
        /// after a successful exchange).
        generation: u64,
        /// The delta payload.
        delta: DeltaPayload,
    },
    /// The sender's membership table (anti-entropy push).
    MembershipPush {
        /// Sender's restart generation (a receiver behind it catches up
        /// at its next refresh).
        generation: u64,
        /// The sender's member table.
        table: MemberTable,
    },
    /// The server's merged membership table (reply to a push or a join).
    MembershipReply {
        /// The serving node's restart generation.
        generation: u64,
        /// The merged member table.
        table: MemberTable,
    },
    /// The `dudd-join` handshake: assign `addr` a stable member id.
    JoinRequest {
        /// The joiner's restart generation (0 — it has none yet).
        generation: u64,
        /// The joiner's exchange listen address.
        addr: SocketAddr,
    },
}

/// Body of a [`ExchangeKind::DeltaPush`]/[`ExchangeKind::DeltaReply`]
/// frame: the sender's scalars in full plus bucket **set** operations
/// against a baseline both sides cached after their last completed
/// exchange (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaPayload {
    /// FNV-1a fingerprint of the baseline's canonical peer-state frame
    /// ([`peer_state_fingerprint`]); the receiver refuses the delta
    /// ([`RejectReason::BaselineMismatch`]) when its cached baseline's
    /// fingerprint differs.
    pub baseline_fingerprint: u64,
    /// The sender's current collapse depth (≥ the baseline's — both
    /// sides align the baseline to it before diffing/applying).
    pub collapses: u32,
    /// The sender's zero-bucket weight (shipped in full — one f64).
    pub zero_weight: f64,
    /// The sender's peer id (the reply echoes the initiator's).
    pub id: usize,
    /// The sender's `Ñ` scalar, in full.
    pub n_tilde: f64,
    /// The sender's `q̃` scalar, in full.
    pub q_tilde: f64,
    /// Positive-store set ops: `(index, counter)` pairs in ascending
    /// index order; a counter of exactly `0.0` removes the bucket.
    pub pos: Vec<(i64, f64)>,
    /// Negative-store set ops, same convention.
    pub neg: Vec<(i64, f64)>,
}

impl DeltaPayload {
    /// Total buckets this delta touches (diff cardinality).
    pub fn changed_buckets(&self) -> usize {
        self.pos.len() + self.neg.len()
    }
}

/// 64-bit FNV-1a over a byte string (baseline fingerprints).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint a peer state by hashing its canonical wire frame —
/// bit-identical states (and only those) agree, so two nodes that cached
/// the averaged state of the same completed exchange always match.
pub fn peer_state_fingerprint(s: &PeerState) -> u64 {
    fnv1a64(&encode_peer_state(s))
}

/// [`peer_state_fingerprint`] computed from an already-encoded **full**
/// exchange frame (`Push`/`Reply`): the bytes after the header are
/// exactly the state's canonical encoding, so callers that hold the
/// frame skip a ~16 KiB re-encode. Version-aware — the header is 22
/// bytes for v2 frames and 14 for legacy v1 ones. Returns `None` for a
/// buffer too short to be a full frame (or an unknown version, whose
/// payload offset cannot be known).
pub fn exchange_frame_fingerprint(frame: &[u8]) -> Option<u64> {
    let header = match frame.get(4) {
        Some(&VERSION) => EXCHANGE_HEADER_BYTES,
        Some(&LEGACY_VERSION) => LEGACY_HEADER_BYTES,
        _ => return None,
    };
    if frame.len() <= header {
        return None;
    }
    Some(fnv1a64(&frame[header..]))
}

/// Diff two sorted entry lists into set ops: `(i, c)` where `cur` has a
/// new or changed counter, `(i, 0.0)` where `base` has a bucket `cur`
/// dropped. Bit-level counter comparison, so applying the result
/// reconstructs `cur` exactly.
fn diff_entries(base: &[(i64, f64)], cur: &[(i64, f64)]) -> Vec<(i64, f64)> {
    let mut out = Vec::new();
    let (mut bi, mut ci) = (0usize, 0usize);
    while bi < base.len() || ci < cur.len() {
        match (base.get(bi), cur.get(ci)) {
            (Some(&(ib, _)), Some(&(ic, cc))) if ib == ic => {
                if base[bi].1.to_bits() != cc.to_bits() {
                    out.push((ic, cc));
                }
                bi += 1;
                ci += 1;
            }
            (Some(&(ib, _)), Some(&(ic, _))) if ib < ic => {
                out.push((ib, 0.0));
                bi += 1;
            }
            (_, Some(&(ic, cc))) => {
                out.push((ic, cc));
                ci += 1;
            }
            (Some(&(ib, _)), None) => {
                out.push((ib, 0.0));
                bi += 1;
            }
            (None, None) => unreachable!("loop condition"),
        }
    }
    out
}

/// Apply set ops to a sorted entry list (two-pointer merge; delta wins,
/// zero counters remove).
fn apply_entry_delta(base: &[(i64, f64)], delta: &[(i64, f64)]) -> Vec<(i64, f64)> {
    let mut out = Vec::with_capacity(base.len() + delta.len());
    let (mut bi, mut di) = (0usize, 0usize);
    while bi < base.len() || di < delta.len() {
        match (base.get(bi), delta.get(di)) {
            (Some(&(ib, cb)), Some(&(id, _))) if ib < id => {
                out.push((ib, cb));
                bi += 1;
            }
            (Some(&(ib, _)), Some(&(id, cd))) if ib == id => {
                if cd != 0.0 {
                    out.push((id, cd));
                }
                bi += 1;
                di += 1;
            }
            (_, Some(&(id, cd))) => {
                if cd != 0.0 {
                    out.push((id, cd));
                }
                di += 1;
            }
            (Some(&(ib, cb)), None) => {
                out.push((ib, cb));
                bi += 1;
            }
            (None, None) => unreachable!("loop condition"),
        }
    }
    out
}

/// Build the delta that turns `baseline` into `current`, or `None` when
/// no exact delta exists (different α₀ lineage, or a collapse depth that
/// went *backwards* — impossible within one restart generation, so a
/// `None` here means the caller's baseline is stale and a full frame
/// must be sent). `fingerprint` is the cached
/// [`peer_state_fingerprint`] of `baseline` (cached so the ~16 KiB
/// re-encode is not paid per exchange).
pub fn delta_payload(
    baseline: &PeerState,
    fingerprint: u64,
    current: &PeerState,
) -> Option<DeltaPayload> {
    if !current
        .sketch
        .mapping()
        .same_lineage(baseline.sketch.mapping())
        || current.sketch.collapses() < baseline.sketch.collapses()
        || current.sketch.max_buckets() != baseline.sketch.max_buckets()
    {
        return None;
    }
    let mut base = baseline.sketch.clone();
    base.align_to_collapses(current.sketch.collapses());
    Some(DeltaPayload {
        baseline_fingerprint: fingerprint,
        collapses: current.sketch.collapses(),
        zero_weight: current.sketch.zero_weight(),
        id: current.id,
        n_tilde: current.n_tilde,
        q_tilde: current.q_tilde,
        pos: diff_entries(
            &base.positive_store().entries(),
            &current.sketch.positive_store().entries(),
        ),
        neg: diff_entries(
            &base.negative_store().entries(),
            &current.sketch.negative_store().entries(),
        ),
    })
}

/// Reconstruct the sender's full state from its delta and the shared
/// baseline. The caller must already have verified
/// `delta.baseline_fingerprint` against its cached fingerprint — this
/// function only checks structural applicability (collapse depth).
/// Reconstruction is bit-exact: set semantics on bit-compared counters,
/// deterministic collapse alignment, scalars shipped in full.
pub fn apply_delta(baseline: &PeerState, delta: &DeltaPayload) -> Result<PeerState, CodecError> {
    if delta.collapses < baseline.sketch.collapses() {
        return Err(CodecError::BadParams(format!(
            "delta collapse depth {} behind the baseline's {}",
            delta.collapses,
            baseline.sketch.collapses()
        )));
    }
    let mut sketch = baseline.sketch.clone();
    sketch.align_to_collapses(delta.collapses);
    let pos = apply_entry_delta(&sketch.positive_store().entries(), &delta.pos);
    let neg = apply_entry_delta(&sketch.negative_store().entries(), &delta.neg);
    sketch.load_raw(delta.zero_weight, &pos, &neg);
    Ok(PeerState {
        id: delta.id,
        sketch,
        n_tilde: delta.n_tilde,
        q_tilde: delta.q_tilde,
    })
}

/// Wire size of a delta frame without materializing it (the sender picks
/// delta vs full by comparing this with `22 +`
/// [`peer_state_wire_size`]).
pub fn delta_wire_size(delta: &DeltaPayload) -> usize {
    // header(22) + fingerprint(8) + collapses(4) + zero(8) + id(8)
    // + n(8) + q(8) + 2 × len(8) + 16/entry
    82 + 16 * delta.changed_buckets()
}

/// Encode a socket address: `family u8 (4|6) | ip bytes | port u16 LE`.
fn encode_socket_addr_into(addr: SocketAddr, out: &mut Vec<u8>) {
    match addr.ip() {
        IpAddr::V4(ip) => {
            out.push(4);
            out.extend_from_slice(&ip.octets());
        }
        IpAddr::V6(ip) => {
            out.push(6);
            out.extend_from_slice(&ip.octets());
        }
    }
    out.extend_from_slice(&addr.port().to_le_bytes());
}

fn decode_socket_addr_from(r: &mut Reader<'_>) -> Result<SocketAddr, CodecError> {
    let ip: IpAddr = match r.u8()? {
        4 => Ipv4Addr::from(<[u8; 4]>::try_from(r.take(4)?).unwrap()).into(),
        6 => Ipv6Addr::from(<[u8; 16]>::try_from(r.take(16)?).unwrap()).into(),
        other => {
            return Err(CodecError::BadParams(format!(
                "unknown address family {other}"
            )))
        }
    };
    let port = u16::from_le_bytes(r.take(2)?.try_into().unwrap());
    Ok(SocketAddr::new(ip, port))
}

/// Smallest possible member-entry encoding (IPv4 address): the hostile
/// length guard of the table decoder.
const MIN_MEMBER_ENTRY_BYTES: usize = 8 + 8 + 1 + 1 + 4 + 2;

fn encode_member_table_into(t: &MemberTable, out: &mut Vec<u8>) {
    out.extend_from_slice(&(t.len() as u64).to_le_bytes());
    for e in t.iter() {
        out.extend_from_slice(&e.id.to_le_bytes());
        out.extend_from_slice(&e.incarnation.to_le_bytes());
        out.push(e.status.code());
        encode_socket_addr_into(e.addr, out);
    }
}

fn decode_member_table_from(r: &mut Reader<'_>) -> Result<MemberTable, CodecError> {
    let count = r.len_field(MIN_MEMBER_ENTRY_BYTES)?;
    let mut table = MemberTable::new();
    for _ in 0..count {
        let id = r.u64()?;
        let incarnation = r.u64()?;
        let status = MemberStatus::from_code(r.u8()?).ok_or_else(|| {
            CodecError::BadParams("unknown member status code".into())
        })?;
        let addr = decode_socket_addr_from(r)?;
        table.upsert(MemberEntry {
            id,
            addr,
            incarnation,
            status,
        });
    }
    Ok(table)
}

/// Canonical encoding of a member table (`docs/PROTOCOL.md` §9):
/// entries in ascending id order, so two converged nodes' tables are
/// byte-identical — the churn acceptance test compares these bytes.
pub fn encode_member_table(t: &MemberTable) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 40 * t.len());
    encode_member_table_into(t, &mut out);
    out
}

/// Decode a canonical member-table payload.
pub fn decode_member_table(buf: &[u8]) -> Result<MemberTable, CodecError> {
    decode_member_table_from(&mut Reader::new(buf))
}

fn exchange_header(kind: ExchangeKind, generation: u64, trace_id: u64, out: &mut Vec<u8>) {
    out.extend_from_slice(EXCHANGE_MAGIC);
    out.push(VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&generation.to_le_bytes());
    out.extend_from_slice(&trace_id.to_le_bytes());
}

/// Encode a push frame (initiator's pre-round state), untraced
/// (trace id 0).
pub fn encode_exchange_push(generation: u64, state: &PeerState) -> Vec<u8> {
    encode_exchange_push_traced(generation, 0, state)
}

/// [`encode_exchange_push`] stamped with the initiator's exchange
/// trace id.
pub fn encode_exchange_push_traced(
    generation: u64,
    trace_id: u64,
    state: &PeerState,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(EXCHANGE_HEADER_BYTES + peer_state_wire_size(state));
    exchange_header(ExchangeKind::Push, generation, trace_id, &mut out);
    encode_peer_state_into(state, &mut out);
    out
}

/// Encode a reply frame (the averaged state both sides adopt),
/// untraced (trace id 0).
pub fn encode_exchange_reply(generation: u64, state: &PeerState) -> Vec<u8> {
    encode_exchange_reply_traced(generation, 0, state)
}

/// [`encode_exchange_reply`] echoing the push's trace id — the serve
/// side's half of the cross-node span join.
pub fn encode_exchange_reply_traced(
    generation: u64,
    trace_id: u64,
    state: &PeerState,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(EXCHANGE_HEADER_BYTES + peer_state_wire_size(state));
    exchange_header(ExchangeKind::Reply, generation, trace_id, &mut out);
    encode_peer_state_into(state, &mut out);
    out
}

/// Encode a reject frame (cancelled exchange, §7.2), untraced.
pub fn encode_exchange_reject(generation: u64, reason: RejectReason) -> Vec<u8> {
    encode_exchange_reject_traced(generation, 0, reason)
}

/// [`encode_exchange_reject`] echoing the refused push's trace id, so
/// cancelled exchanges join into causal timelines too.
pub fn encode_exchange_reject_traced(
    generation: u64,
    trace_id: u64,
    reason: RejectReason,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(EXCHANGE_HEADER_BYTES + 1);
    exchange_header(ExchangeKind::Reject, generation, trace_id, &mut out);
    out.push(reason.code());
    out
}

fn encode_delta_frame(
    kind: ExchangeKind,
    generation: u64,
    trace_id: u64,
    delta: &DeltaPayload,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(delta_wire_size(delta));
    exchange_header(kind, generation, trace_id, &mut out);
    out.extend_from_slice(&delta.baseline_fingerprint.to_le_bytes());
    out.extend_from_slice(&delta.collapses.to_le_bytes());
    out.extend_from_slice(&delta.zero_weight.to_le_bytes());
    out.extend_from_slice(&(delta.id as u64).to_le_bytes());
    out.extend_from_slice(&delta.n_tilde.to_le_bytes());
    out.extend_from_slice(&delta.q_tilde.to_le_bytes());
    for ops in [&delta.pos, &delta.neg] {
        out.extend_from_slice(&(ops.len() as u64).to_le_bytes());
        for &(i, c) in ops {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
    out
}

/// Encode a delta push frame (initiator's state vs the pair baseline),
/// untraced (trace id 0).
pub fn encode_exchange_delta_push(generation: u64, delta: &DeltaPayload) -> Vec<u8> {
    encode_delta_frame(ExchangeKind::DeltaPush, generation, 0, delta)
}

/// [`encode_exchange_delta_push`] stamped with the initiator's
/// exchange trace id.
pub fn encode_exchange_delta_push_traced(
    generation: u64,
    trace_id: u64,
    delta: &DeltaPayload,
) -> Vec<u8> {
    encode_delta_frame(ExchangeKind::DeltaPush, generation, trace_id, delta)
}

/// Encode a delta reply frame (averaged state vs the same baseline),
/// untraced (trace id 0).
pub fn encode_exchange_delta_reply(generation: u64, delta: &DeltaPayload) -> Vec<u8> {
    encode_delta_frame(ExchangeKind::DeltaReply, generation, 0, delta)
}

/// [`encode_exchange_delta_reply`] echoing the push's trace id.
pub fn encode_exchange_delta_reply_traced(
    generation: u64,
    trace_id: u64,
    delta: &DeltaPayload,
) -> Vec<u8> {
    encode_delta_frame(ExchangeKind::DeltaReply, generation, trace_id, delta)
}

fn encode_membership_frame(
    kind: ExchangeKind,
    generation: u64,
    table: &MemberTable,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(EXCHANGE_HEADER_BYTES + 8 + 40 * table.len());
    exchange_header(kind, generation, 0, &mut out);
    encode_member_table_into(table, &mut out);
    out
}

/// Encode a membership anti-entropy push.
pub fn encode_membership_push(generation: u64, table: &MemberTable) -> Vec<u8> {
    encode_membership_frame(ExchangeKind::MembershipPush, generation, table)
}

/// Encode a membership reply (the server's merged table).
pub fn encode_membership_reply(generation: u64, table: &MemberTable) -> Vec<u8> {
    encode_membership_frame(ExchangeKind::MembershipReply, generation, table)
}

/// Encode a `dudd-join` handshake request.
pub fn encode_join_request(generation: u64, addr: SocketAddr) -> Vec<u8> {
    let mut out = Vec::with_capacity(EXCHANGE_HEADER_BYTES + 19);
    exchange_header(ExchangeKind::JoinRequest, generation, 0, &mut out);
    encode_socket_addr_into(addr, &mut out);
    out
}

fn decode_delta_from(r: &mut Reader<'_>) -> Result<DeltaPayload, CodecError> {
    let baseline_fingerprint = r.u64()?;
    let collapses = r.u32()?;
    let zero_weight = r.f64()?;
    let id = r.u64()? as usize;
    let n_tilde = r.f64()?;
    let q_tilde = r.f64()?;
    let pos_len = r.len_field(16)?;
    let mut pos = Vec::with_capacity(pos_len);
    for _ in 0..pos_len {
        pos.push((r.i64()?, r.f64()?));
    }
    let neg_len = r.len_field(16)?;
    let mut neg = Vec::with_capacity(neg_len);
    for _ in 0..neg_len {
        neg.push((r.i64()?, r.f64()?));
    }
    Ok(DeltaPayload {
        baseline_fingerprint,
        collapses,
        zero_weight,
        id,
        n_tilde,
        q_tilde,
        pos,
        neg,
    })
}

/// Decode any exchange frame, validating magic, version, and kind.
/// Accepts both the current version-2 header and the legacy version-1
/// one; callers that care about the trace id use
/// [`decode_exchange_traced`].
pub fn decode_exchange(buf: &[u8]) -> Result<ExchangeFrame, CodecError> {
    decode_exchange_traced(buf).map(|(frame, _)| frame)
}

/// [`decode_exchange`] that also returns the header's exchange trace
/// id (0 for untraced and for legacy version-1 frames).
pub fn decode_exchange_traced(buf: &[u8]) -> Result<(ExchangeFrame, u64), CodecError> {
    let mut r = Reader::new(buf);
    if r.take(4)? != EXCHANGE_MAGIC {
        return Err(CodecError::BadMagic);
    }
    let version = r.u8()?;
    if version != VERSION && version != LEGACY_VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let kind = r.u8()?;
    let generation = r.u64()?;
    let trace_id = if version == VERSION { r.u64()? } else { 0 };
    let frame = match kind {
        1 => Ok(ExchangeFrame::Push {
            generation,
            state: decode_peer_state_from(&mut r)?,
        }),
        2 => Ok(ExchangeFrame::Reply {
            generation,
            state: decode_peer_state_from(&mut r)?,
        }),
        3 => Ok(ExchangeFrame::Reject {
            generation,
            reason: RejectReason::from_code(r.u8()?)?,
        }),
        4 => Ok(ExchangeFrame::DeltaPush {
            generation,
            delta: decode_delta_from(&mut r)?,
        }),
        5 => Ok(ExchangeFrame::DeltaReply {
            generation,
            delta: decode_delta_from(&mut r)?,
        }),
        6 => Ok(ExchangeFrame::MembershipPush {
            generation,
            table: decode_member_table_from(&mut r)?,
        }),
        7 => Ok(ExchangeFrame::MembershipReply {
            generation,
            table: decode_member_table_from(&mut r)?,
        }),
        8 => Ok(ExchangeFrame::JoinRequest {
            generation,
            addr: decode_socket_addr_from(&mut r)?,
        }),
        other => Err(CodecError::BadKind(other)),
    }?;
    Ok((frame, trace_id))
}

/// Wire size of a peer state without materializing the frame (used for
/// the simulator's traffic accounting).
pub fn peer_state_wire_size(s: &PeerState) -> usize {
    // header(4+1) + alpha(8) + collapses(4) + m(8) + zero(8) = 33
    // + 2 * len(8) + 16/bucket + id(8) + n(8) + q(8)
    33 + 16 + 16 * s.sketch.bucket_count() + 24
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{default_rng, Rng};
    use crate::sketch::{DenseStore, SparseStore};

    fn sample_sketch() -> UddSketch<SparseStore> {
        let mut s: UddSketch<SparseStore> = UddSketch::new(0.001, 64).unwrap();
        let mut r = default_rng(1);
        for _ in 0..5_000 {
            s.insert(10f64.powf(r.next_f64() * 5.0 - 1.0));
        }
        s.insert(-3.5);
        s.insert(0.0);
        s
    }

    #[test]
    fn sketch_roundtrip_is_exact() {
        let s = sample_sketch();
        let buf = encode_sketch(&s);
        let d: UddSketch<SparseStore> = decode_sketch(&buf).unwrap();
        assert_eq!(d.collapses(), s.collapses());
        assert_eq!(d.count(), s.count());
        assert_eq!(d.zero_weight(), s.zero_weight());
        assert_eq!(
            d.positive_store().entries(),
            s.positive_store().entries()
        );
        assert_eq!(
            d.negative_store().entries(),
            s.negative_store().entries()
        );
        for q in [0.01, 0.5, 0.99] {
            assert_eq!(d.quantile(q).unwrap(), s.quantile(q).unwrap());
        }
    }

    #[test]
    fn cross_store_roundtrip() {
        // Encode sparse, decode dense: same answers.
        let s = sample_sketch();
        let buf = encode_sketch(&s);
        let d: UddSketch<DenseStore> = decode_sketch(&buf).unwrap();
        assert_eq!(d.quantile(0.9).unwrap(), s.quantile(0.9).unwrap());
    }

    #[test]
    fn peer_state_roundtrip() {
        let st = PeerState::init(7, &[1.0, 2.0, 3.0], 0.01, 32).unwrap();
        let buf = encode_peer_state(&st);
        assert_eq!(buf.len(), peer_state_wire_size(&st));
        let d = decode_peer_state(&buf).unwrap();
        assert_eq!(d.id, 7);
        assert_eq!(d.n_tilde, 3.0);
        assert_eq!(d.q_tilde, 0.0);
        assert_eq!(
            d.sketch.positive_store().entries(),
            st.sketch.positive_store().entries()
        );
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(
            decode_sketch::<SparseStore>(b"np").unwrap_err(),
            CodecError::Truncated(0)
        );
        assert_eq!(
            decode_sketch::<SparseStore>(b"nope").unwrap_err(),
            CodecError::BadMagic
        );
        assert_eq!(
            decode_sketch::<SparseStore>(b"XXXX\x01aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa")
                .unwrap_err(),
            CodecError::BadMagic
        );
        let mut ok = encode_sketch(&sample_sketch());
        ok[4] = 99; // version byte
        assert_eq!(
            decode_sketch::<SparseStore>(&ok).unwrap_err(),
            CodecError::BadVersion(99)
        );
    }

    #[test]
    fn truncation_detected_everywhere() {
        let buf = encode_peer_state(&PeerState::init(0, &[5.0, 6.0], 0.01, 32).unwrap());
        for cut in 0..buf.len() {
            let r = decode_peer_state(&buf[..cut]);
            assert!(r.is_err(), "cut at {cut} should fail");
        }
        assert!(decode_peer_state(&buf).is_ok());
    }

    #[test]
    fn exchange_push_and_reply_roundtrip() {
        let st = PeerState::init(3, &[1.0, 2.5, 9.0], 0.01, 32).unwrap();
        for (buf, want_push) in [
            (encode_exchange_push(7, &st), true),
            (encode_exchange_reply(7, &st), false),
        ] {
            match decode_exchange(&buf).unwrap() {
                ExchangeFrame::Push { generation, state } if want_push => {
                    assert_eq!(generation, 7);
                    assert_eq!(state.id, 3);
                    assert_eq!(state.n_tilde, 3.0);
                }
                ExchangeFrame::Reply { generation, state } if !want_push => {
                    assert_eq!(generation, 7);
                    assert_eq!(
                        state.sketch.positive_store().entries(),
                        st.sketch.positive_store().entries()
                    );
                }
                other => panic!("wrong frame decoded: {other:?}"),
            }
        }
    }

    #[test]
    fn exchange_reject_roundtrip_all_reasons() {
        for reason in [
            RejectReason::Busy,
            RejectReason::StaleGeneration,
            RejectReason::Lineage,
            RejectReason::Malformed,
            RejectReason::BaselineMismatch,
            RejectReason::NoMembership,
        ] {
            let buf = encode_exchange_reject(42, reason);
            match decode_exchange(&buf).unwrap() {
                ExchangeFrame::Reject { generation, reason: r } => {
                    assert_eq!(generation, 42);
                    assert_eq!(r, reason);
                }
                other => panic!("wrong frame: {other:?}"),
            }
        }
    }

    #[test]
    fn exchange_frame_rejects_bad_inputs() {
        let st = PeerState::init(0, &[5.0], 0.01, 32).unwrap();
        let good = encode_exchange_push(1, &st);

        assert_eq!(decode_exchange(b"UDD").unwrap_err(), CodecError::Truncated(0));
        assert_eq!(
            decode_exchange(b"UDDSxxxxxxxxxxxxxxxx").unwrap_err(),
            CodecError::BadMagic,
            "sketch magic is not exchange magic"
        );
        let mut bad = good.clone();
        bad[4] = 99;
        assert_eq!(decode_exchange(&bad).unwrap_err(), CodecError::BadVersion(99));
        let mut bad = good.clone();
        bad[5] = 17;
        assert_eq!(decode_exchange(&bad).unwrap_err(), CodecError::BadKind(17));
        let mut bad = encode_exchange_reject(0, RejectReason::Busy);
        *bad.last_mut().unwrap() = 200;
        assert!(matches!(
            decode_exchange(&bad).unwrap_err(),
            CodecError::BadParams(_)
        ));
        for cut in 0..good.len() {
            assert!(decode_exchange(&good[..cut]).is_err(), "cut {cut}");
        }
    }

    fn gossip_state(id: usize, values: &[f64]) -> PeerState {
        PeerState::init(id, values, 0.01, 64).unwrap()
    }

    fn assert_states_bit_equal(a: &PeerState, b: &PeerState) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.n_tilde.to_bits(), b.n_tilde.to_bits());
        assert_eq!(a.q_tilde.to_bits(), b.q_tilde.to_bits());
        assert_eq!(a.sketch.collapses(), b.sketch.collapses());
        assert_eq!(a.sketch.zero_weight().to_bits(), b.sketch.zero_weight().to_bits());
        assert_eq!(
            a.sketch.positive_store().entries(),
            b.sketch.positive_store().entries()
        );
        assert_eq!(
            a.sketch.negative_store().entries(),
            b.sketch.negative_store().entries()
        );
    }

    #[test]
    fn delta_roundtrip_reconstructs_bit_for_bit() {
        let baseline = gossip_state(3, &[1.0, 2.0, 3.0, 50.0, -4.0, 0.0]);
        let fp = peer_state_fingerprint(&baseline);

        // Evolve a copy the way gossip does: average with another state.
        let mut current = baseline.clone();
        let mut other = gossip_state(9, &[7.0, 8.0, 900.0]);
        PeerState::exchange(&mut current, &mut other).unwrap();

        let delta = delta_payload(&baseline, fp, &current).expect("same lineage");
        assert_eq!(delta.baseline_fingerprint, fp);
        let frame = encode_exchange_delta_push(11, &delta);
        assert_eq!(frame.len(), delta_wire_size(&delta));
        let decoded = match decode_exchange(&frame).unwrap() {
            ExchangeFrame::DeltaPush { generation, delta } => {
                assert_eq!(generation, 11);
                delta
            }
            other => panic!("wrong frame: {other:?}"),
        };
        assert_eq!(decoded, delta);
        let rebuilt = apply_delta(&baseline, &decoded).unwrap();
        assert_states_bit_equal(&rebuilt, &current);
        assert_eq!(
            peer_state_fingerprint(&rebuilt),
            peer_state_fingerprint(&current)
        );
    }

    #[test]
    fn delta_handles_removed_buckets_and_identity() {
        // Identity delta: zero set ops, reconstruction exact.
        let s = gossip_state(0, &[1.0, 10.0, 100.0]);
        let fp = peer_state_fingerprint(&s);
        let delta = delta_payload(&s, fp, &s).unwrap();
        assert_eq!(delta.changed_buckets(), 0);
        assert_states_bit_equal(&apply_delta(&s, &delta).unwrap(), &s);

        // A state that *dropped* buckets (reseed-free shrink is synthetic,
        // but the wire format must support counter-to-zero set ops).
        let mut shrunk = s.clone();
        let entries = shrunk.sketch.positive_store().entries();
        shrunk
            .sketch
            .load_raw(0.0, &entries[..entries.len() - 1], &[]);
        let delta = delta_payload(&s, fp, &shrunk).unwrap();
        assert!(delta.pos.iter().any(|&(_, c)| c == 0.0), "{delta:?}");
        let rebuilt = apply_delta(&s, &delta).unwrap();
        assert_states_bit_equal(&rebuilt, &shrunk);
    }

    #[test]
    fn delta_reply_roundtrips_and_rejects_collapse_regression() {
        let baseline = gossip_state(1, &[5.0, 6.0]);
        let fp = peer_state_fingerprint(&baseline);
        let delta = delta_payload(&baseline, fp, &baseline).unwrap();
        let frame = encode_exchange_delta_reply(4, &delta);
        assert!(matches!(
            decode_exchange(&frame).unwrap(),
            ExchangeFrame::DeltaReply { generation: 4, .. }
        ));

        // A delta whose collapse depth is behind the baseline cannot apply.
        let mut deep = baseline.clone();
        deep.sketch.force_collapse();
        let stale = delta_payload(&baseline, fp, &baseline).unwrap();
        assert!(matches!(
            apply_delta(&deep, &stale).unwrap_err(),
            CodecError::BadParams(_)
        ));
        // And the sender side refuses to build one against a deeper base.
        assert!(delta_payload(&deep, fp, &baseline).is_none());
    }

    #[test]
    fn delta_aligns_baseline_across_collapses() {
        // Current state collapsed past the baseline: the delta carries the
        // new depth and application re-aligns deterministically.
        let baseline = gossip_state(2, &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0]);
        let fp = peer_state_fingerprint(&baseline);
        let mut current = baseline.clone();
        current.sketch.force_collapse();
        current.n_tilde += 1.0;
        let delta = delta_payload(&baseline, fp, &current).unwrap();
        assert_eq!(delta.collapses, current.sketch.collapses());
        let rebuilt = apply_delta(&baseline, &delta).unwrap();
        assert_states_bit_equal(&rebuilt, &current);
    }

    #[test]
    fn delta_frame_truncation_detected_everywhere() {
        let baseline = gossip_state(5, &[1.0, 2.0, 3.0]);
        let fp = peer_state_fingerprint(&baseline);
        let mut current = baseline.clone();
        let mut other = gossip_state(6, &[40.0, 50.0]);
        PeerState::exchange(&mut current, &mut other).unwrap();
        let frame =
            encode_exchange_delta_push(1, &delta_payload(&baseline, fp, &current).unwrap());
        for cut in 0..frame.len() {
            assert!(decode_exchange(&frame[..cut]).is_err(), "cut {cut}");
        }
        assert!(decode_exchange(&frame).is_ok());
    }

    #[test]
    fn fingerprint_tracks_bit_level_changes() {
        let a = gossip_state(0, &[1.0, 2.0]);
        let b = gossip_state(0, &[1.0, 2.0]);
        assert_eq!(peer_state_fingerprint(&a), peer_state_fingerprint(&b));
        let mut c = b.clone();
        c.n_tilde += 1e-9;
        assert_ne!(peer_state_fingerprint(&a), peer_state_fingerprint(&c));
    }

    #[test]
    fn frame_fingerprint_matches_state_fingerprint() {
        let st = gossip_state(3, &[1.0, 2.0, 3.0]);
        for frame in [encode_exchange_push(9, &st), encode_exchange_reply(9, &st)] {
            assert_eq!(
                exchange_frame_fingerprint(&frame),
                Some(peer_state_fingerprint(&st))
            );
            // The fingerprint covers the payload only, so tracing the
            // frame must not move it (deltas stay applicable across
            // traced/untraced pairs).
            let traced = encode_exchange_push_traced(9, 0xDEAD_BEEF, &st);
            assert_eq!(
                exchange_frame_fingerprint(&traced),
                Some(peer_state_fingerprint(&st))
            );
            // And a *legacy* v1 frame of the same state agrees too —
            // the 14-byte header is skipped via the version byte.
            let legacy = legacy_frame(&frame);
            assert_eq!(
                exchange_frame_fingerprint(&legacy),
                Some(peer_state_fingerprint(&st))
            );
        }
        // Headers with no payload — and unknown versions, whose payload
        // offset cannot be known — have no fingerprint.
        assert_eq!(exchange_frame_fingerprint(&[0u8; 22]), None);
        let mut empty = [0u8; 22];
        empty[4] = VERSION;
        assert_eq!(exchange_frame_fingerprint(&empty), None);
        let mut empty = [0u8; 14];
        empty[4] = LEGACY_VERSION;
        assert_eq!(exchange_frame_fingerprint(&empty), None);
    }

    /// Rebuild a v2 exchange frame as its version-1 equivalent: same
    /// magic/kind/generation, no trace-id field.
    fn legacy_frame(v2: &[u8]) -> Vec<u8> {
        assert_eq!(v2[4], VERSION);
        let mut out = Vec::with_capacity(v2.len() - 8);
        out.extend_from_slice(&v2[..4]);
        out.push(LEGACY_VERSION);
        out.extend_from_slice(&v2[5..14]); // kind + generation
        out.extend_from_slice(&v2[22..]); // payload (trace id dropped)
        out
    }

    #[test]
    fn traced_frames_roundtrip_and_legacy_v1_still_decodes() {
        let st = PeerState::init(3, &[1.0, 2.5, 9.0], 0.01, 32).unwrap();
        let push = encode_exchange_push_traced(7, 0x1234_5678_9ABC_DEF0, &st);
        let (frame, tid) = decode_exchange_traced(&push).unwrap();
        assert_eq!(tid, 0x1234_5678_9ABC_DEF0);
        assert!(matches!(frame, ExchangeFrame::Push { generation: 7, .. }));

        // Reply and reject echo the push's id.
        let reply = encode_exchange_reply_traced(7, tid, &st);
        assert_eq!(decode_exchange_traced(&reply).unwrap().1, tid);
        let reject =
            encode_exchange_reject_traced(7, tid, RejectReason::Busy);
        let (frame, echoed) = decode_exchange_traced(&reject).unwrap();
        assert_eq!(echoed, tid);
        assert!(matches!(frame, ExchangeFrame::Reject { .. }));

        // Delta frames carry the id too.
        let fp = peer_state_fingerprint(&st);
        let delta = delta_payload(&st, fp, &st).unwrap();
        for buf in [
            encode_exchange_delta_push_traced(7, tid, &delta),
            encode_exchange_delta_reply_traced(7, tid, &delta),
        ] {
            assert_eq!(decode_exchange_traced(&buf).unwrap().1, tid);
        }

        // Untraced encoders stamp 0.
        assert_eq!(
            decode_exchange_traced(&encode_exchange_push(7, &st)).unwrap().1,
            0
        );

        // A version-1 peer's frame still decodes, with an implied id of
        // 0 — rolling upgrades keep exchanging.
        let legacy = legacy_frame(&push);
        let (frame, tid) = decode_exchange_traced(&legacy).unwrap();
        assert_eq!(tid, 0);
        match frame {
            ExchangeFrame::Push { generation, state } => {
                assert_eq!(generation, 7);
                assert_eq!(state.id, 3);
                assert_eq!(state.n_tilde, 3.0);
            }
            other => panic!("wrong frame decoded: {other:?}"),
        }
        // Truncation still lands everywhere on the legacy layout.
        for cut in 0..legacy.len() {
            assert!(decode_exchange(&legacy[..cut]).is_err(), "cut {cut}");
        }
    }

    fn sample_table() -> MemberTable {
        let mut t = MemberTable::new();
        t.upsert(MemberEntry::alive(0, "127.0.0.1:7001".parse().unwrap()));
        t.upsert(MemberEntry {
            id: 1,
            addr: "10.0.0.3:7400".parse().unwrap(),
            incarnation: 4,
            status: MemberStatus::Suspect,
        });
        t.upsert(MemberEntry {
            id: 7,
            addr: "[2001:db8::5]:9000".parse().unwrap(),
            incarnation: 2,
            status: MemberStatus::Dead,
        });
        t
    }

    #[test]
    fn member_table_roundtrips_canonically() {
        let t = sample_table();
        let buf = encode_member_table(&t);
        let d = decode_member_table(&buf).unwrap();
        assert_eq!(d, t);
        // Canonical: re-encoding the decode is byte-identical, and a
        // table built in a different insert order encodes the same.
        assert_eq!(encode_member_table(&d), buf);
        let mut entries: Vec<MemberEntry> = t.iter().cloned().collect();
        entries.reverse();
        let mut reordered = MemberTable::new();
        for e in entries {
            reordered.upsert(e);
        }
        assert_eq!(encode_member_table(&reordered), buf);
    }

    #[test]
    fn membership_frames_roundtrip() {
        let t = sample_table();
        for (buf, want_push) in [
            (encode_membership_push(9, &t), true),
            (encode_membership_reply(9, &t), false),
        ] {
            match decode_exchange(&buf).unwrap() {
                ExchangeFrame::MembershipPush { generation, table } if want_push => {
                    assert_eq!(generation, 9);
                    assert_eq!(table, t);
                }
                ExchangeFrame::MembershipReply { generation, table } if !want_push => {
                    assert_eq!(generation, 9);
                    assert_eq!(table, t);
                }
                other => panic!("wrong frame decoded: {other:?}"),
            }
        }
        let addr: SocketAddr = "192.168.7.4:7400".parse().unwrap();
        match decode_exchange(&encode_join_request(0, addr)).unwrap() {
            ExchangeFrame::JoinRequest { generation, addr: a } => {
                assert_eq!(generation, 0);
                assert_eq!(a, addr);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn membership_frames_reject_bad_inputs() {
        let t = sample_table();
        let good = encode_membership_push(1, &t);
        for cut in 0..good.len() {
            assert!(decode_exchange(&good[..cut]).is_err(), "cut {cut}");
        }
        // Unknown status code.
        let mut bad = good.clone();
        bad[22 + 8 + 16] = 9; // first entry's status byte
        assert!(matches!(
            decode_exchange(&bad).unwrap_err(),
            CodecError::BadParams(_)
        ));
        // Unknown address family.
        let mut bad = good.clone();
        bad[22 + 8 + 17] = 5; // first entry's family byte
        assert!(matches!(
            decode_exchange(&bad).unwrap_err(),
            CodecError::BadParams(_)
        ));
        // Hostile entry count: refused before any allocation.
        let mut bad = good;
        bad[22..30].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            decode_exchange(&bad).unwrap_err(),
            CodecError::Truncated(_)
        ));
        // Truncated join request.
        let join = encode_join_request(0, "127.0.0.1:1".parse().unwrap());
        for cut in 0..join.len() {
            assert!(decode_exchange(&join[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn hostile_length_prefix_rejected_before_allocation() {
        // Patch the positive-store length field of a valid sketch frame to
        // an absurd count: the decoder must fail fast, not reserve memory.
        let s = sample_sketch();
        let mut buf = encode_sketch(&s);
        // Layout: magic(4) version(1) alpha(8) collapses(4) m(8) zero(8),
        // then pos_len at offset 33.
        buf[33..41].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            decode_sketch::<SparseStore>(&buf).unwrap_err(),
            CodecError::Truncated(_)
        ));
    }
}
