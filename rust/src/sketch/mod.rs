//! Quantile sketches with relative value error.
//!
//! * [`UddSketch`] — the paper's sequential algorithm [11]: DDSketch's
//!   logarithmic bucketing plus the **uniform collapse** (Algorithm 2),
//!   giving an α-accurate (0,1)-sketch in the turnstile model.
//! * [`DdSketch`] — the predecessor baseline [17] with the
//!   collapse-first-two strategy (α-accurate only for (q₀,1)).
//! * [`ExactQuantiles`] — exact oracle (Definition 2) for validation.
//! * [`LogMapping`] — the shared index map `i = ⌈log_γ x⌉`.
//!
//! Counters are `f64`: the gossip protocol averages sketches, so counts
//! become fractional; the turnstile model admits transiently negative
//! weights.

#![forbid(unsafe_code)]

pub mod codec;
mod ddsketch;
mod exact;
mod store;
mod uddsketch;

pub use codec::{
    apply_delta, decode_exchange, decode_member_table, decode_peer_state, decode_sketch,
    delta_payload, delta_wire_size, encode_exchange_delta_push, encode_exchange_delta_reply,
    encode_exchange_push, encode_exchange_reject, encode_exchange_reply, encode_join_request,
    encode_member_table, encode_membership_push, encode_membership_reply, encode_peer_state,
    encode_sketch, exchange_frame_fingerprint, peer_state_fingerprint, CodecError,
    DeltaPayload, ExchangeFrame, ExchangeKind, RejectReason,
};
pub use ddsketch::DdSketch;
pub use exact::ExactQuantiles;
pub use store::{collapsed_index, DenseStore, SparseStore, Store, VecStore};
pub use uddsketch::UddSketch;

/// One query interface over every quantile surface the crate serves.
///
/// Three read paths answer quantile queries — the sequential
/// [`UddSketch`], the service's local
/// [`Snapshot`](crate::service::Snapshot) (exact epoch fold of this
/// node's stream), and the gossip loop's
/// [`GlobalView`](crate::service::GlobalView) (network-converged estimate
/// of the fleet's *union* stream, Algorithm 6). They differ in what
/// population they describe, not in how they are asked; this trait pins
/// the shared contract so monitoring and verification code can be written
/// once.
///
/// ```
/// use duddsketch::sketch::{QuantileReader, UddSketch};
///
/// fn p99(reader: &dyn QuantileReader) -> Option<f64> {
///     reader.quantile(0.99).ok()
/// }
///
/// let mut s: UddSketch = UddSketch::new(0.01, 256).unwrap();
/// s.extend(&[1.0, 2.0, 3.0]);
/// assert!(p99(&s).is_some());
/// ```
pub trait QuantileReader {
    /// Estimate the inferior q-quantile (Definition 2) of the summarized
    /// population.
    fn quantile(&self, q: f64) -> Result<f64, SketchError>;

    /// Estimated CDF at `x`: the fraction of the population ≤ x.
    fn cdf(&self, x: f64) -> Result<f64, SketchError>;

    /// Summarized population size (the stream length for insert-only
    /// workloads; an estimate for network-converged views).
    fn count(&self) -> f64;

    /// Batch quantile queries.
    fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        qs.iter().map(|&q| self.quantile(q)).collect()
    }

    /// True when no weight is summarized.
    fn is_empty(&self) -> bool {
        self.count() <= 0.0
    }
}

/// Errors surfaced by sketch construction and queries.
///
/// (`Display` is hand-written — thiserror is unavailable offline,
/// DESIGN.md §6.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SketchError {
    /// α must lie in (0, 1).
    InvalidAlpha(f64),
    /// The bucket budget must allow at least one collapse pair.
    InvalidBuckets(usize),
    /// Quantile parameter out of [0, 1].
    InvalidQuantile(f64),
    /// Query on an empty sketch.
    Empty,
    /// Merging sketches with different initial α lineages.
    IncompatibleAlpha(f64, f64),
    /// Value outside the sketch's supported domain.
    UnsupportedValue(f64),
}

impl std::fmt::Display for SketchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SketchError::InvalidAlpha(a) => {
                write!(f, "alpha must be in (0,1), got {a}")
            }
            SketchError::InvalidBuckets(m) => {
                write!(f, "max buckets must be >= 2, got {m}")
            }
            SketchError::InvalidQuantile(q) => {
                write!(f, "quantile q must be in [0,1], got {q}")
            }
            SketchError::Empty => write!(f, "sketch is empty"),
            SketchError::IncompatibleAlpha(a, b) => {
                write!(f, "incompatible sketches: alpha0 {a} vs {b}")
            }
            SketchError::UnsupportedValue(x) => write!(
                f,
                "value {x} not representable (supported domain: finite reals)"
            ),
        }
    }
}

impl std::error::Error for SketchError {}

/// The logarithmic bucket mapping shared by DDSketch and UDDSketch.
///
/// With `γ = (1+α)/(1−α)`, bucket `i` covers `(γ^(i−1), γ^i]` and the
/// mid-point estimate `2γ^i/(γ+1)` is within relative error α of every
/// value in the bucket (Definition 4).
#[derive(Debug, Clone, Copy)]
pub struct LogMapping {
    alpha0: f64,
    /// Number of uniform collapses applied: `γ = γ₀^(2^k)`.
    collapses: u32,
    gamma: f64,
    ln_gamma: f64,
    inv_ln_gamma: f64,
}

impl LogMapping {
    /// Build from the user accuracy parameter α₀ ∈ (0, 1).
    pub fn new(alpha0: f64) -> Result<Self, SketchError> {
        if !(alpha0 > 0.0 && alpha0 < 1.0) || !alpha0.is_finite() {
            return Err(SketchError::InvalidAlpha(alpha0));
        }
        let gamma = (1.0 + alpha0) / (1.0 - alpha0);
        let ln_gamma = gamma.ln();
        Ok(Self {
            alpha0,
            collapses: 0,
            gamma,
            ln_gamma,
            inv_ln_gamma: 1.0 / ln_gamma,
        })
    }

    /// The initial accuracy parameter α₀.
    pub fn alpha0(&self) -> f64 {
        self.alpha0
    }

    /// Current γ (grows as γ ← γ² on every uniform collapse).
    pub fn gamma(&self) -> f64 {
        self.gamma
    }

    /// Current error bound `α = (γ−1)/(γ+1)` (equals
    /// `2α/(1+α²)` applied `collapses` times to α₀, per Lemma 1).
    pub fn alpha(&self) -> f64 {
        (self.gamma - 1.0) / (self.gamma + 1.0)
    }

    /// Number of uniform collapses applied so far.
    pub fn collapses(&self) -> u32 {
        self.collapses
    }

    /// Bucket index for a positive value: `i = ⌈log_γ x⌉`.
    #[inline]
    pub fn index(&self, x: f64) -> i64 {
        debug_assert!(x > 0.0);
        (x.ln() * self.inv_ln_gamma).ceil() as i64
    }

    /// Representative value of bucket `i`: `2γ^i/(γ+1)` (Algorithm 6).
    #[inline]
    pub fn value(&self, i: i64) -> f64 {
        2.0 * (i as f64 * self.ln_gamma).exp() / (self.gamma + 1.0)
    }

    /// Lower edge `γ^(i−1)` of bucket `i`.
    pub fn lower_bound(&self, i: i64) -> f64 {
        ((i - 1) as f64 * self.ln_gamma).exp()
    }

    /// Upper edge `γ^i` of bucket `i`.
    pub fn upper_bound(&self, i: i64) -> f64 {
        (i as f64 * self.ln_gamma).exp()
    }

    /// Register one uniform collapse: γ ← γ².
    pub fn on_collapse(&mut self) {
        self.collapses += 1;
        self.gamma = self.gamma * self.gamma;
        self.ln_gamma = 2.0 * self.ln_gamma;
        self.inv_ln_gamma = 1.0 / self.ln_gamma;
    }

    /// True when two mappings originate from the same α₀ (mergeable after
    /// collapse alignment).
    pub fn same_lineage(&self, other: &Self) -> bool {
        self.alpha0.to_bits() == other.alpha0.to_bits()
    }
}

/// Theorem 2: the worst-case accuracy UDDSketch can degrade to when
/// summarizing values in `[x_min, x_max]` with `m` buckets:
/// `α̂ = (γ̃²−1)/(γ̃²+1)`, `γ̃ = (x_max/x_min)^(1/(m−1))`.
pub fn theorem2_bound(x_min: f64, x_max: f64, m: usize) -> f64 {
    assert!(x_min > 0.0 && x_max >= x_min && m >= 2);
    let gamma_tilde = (x_max / x_min).powf(1.0 / (m as f64 - 1.0));
    let g2 = gamma_tilde * gamma_tilde;
    (g2 - 1.0) / (g2 + 1.0)
}

/// Lemma 1: one uniform collapse maps accuracy α to `2α/(1+α²)`.
pub fn alpha_after_collapse(alpha: f64) -> f64 {
    2.0 * alpha / (1.0 + alpha * alpha)
}

/// The rank targeted by the inferior q-quantile (Definition 2):
/// `⌊1 + q(n−1)⌋` for a dataset of (possibly fractional, under gossip
/// averaging) size `n`.
#[inline]
pub fn quantile_rank(q: f64, n: f64) -> f64 {
    (1.0 + q * (n - 1.0)).floor()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_bucket_bounds() {
        let m = LogMapping::new(0.01).unwrap();
        // Bucket i covers (γ^(i-1), γ^i]: the index of any x in the open
        // interval must be i, and the representative is inside the bucket.
        for i in [-50i64, -3, 0, 1, 7, 42] {
            let lo = m.lower_bound(i);
            let hi = m.upper_bound(i);
            let mid = (lo + hi) / 2.0;
            assert_eq!(m.index(mid), i, "i={i}");
            assert_eq!(m.index(hi * (1.0 - 1e-12)), i);
            let v = m.value(i);
            assert!(v > lo && v <= hi * (1.0 + 1e-12));
        }
    }

    #[test]
    fn mapping_relative_error_within_alpha() {
        let m = LogMapping::new(0.02).unwrap();
        // For any x, |value(index(x)) - x| <= alpha * x.
        let mut x = 1e-6;
        while x < 1e9 {
            let est = m.value(m.index(x));
            assert!(
                (est - x).abs() <= m.alpha() * x * (1.0 + 1e-9),
                "x={x} est={est}"
            );
            x *= 1.37;
        }
    }

    #[test]
    fn collapse_updates_gamma_and_alpha() {
        let mut m = LogMapping::new(0.001).unwrap();
        let a0 = m.alpha();
        let g0 = m.gamma();
        m.on_collapse();
        assert!((m.gamma() - g0 * g0).abs() < 1e-12);
        let expect = alpha_after_collapse(a0);
        assert!((m.alpha() - expect).abs() < 1e-12);
        assert_eq!(m.collapses(), 1);
    }

    #[test]
    fn lemma1_index_map_consistency() {
        // After a collapse (γ'=γ²), an item x in bucket i of the old
        // mapping falls in bucket ⌈i/2⌉ of the new mapping.
        let mut m = LogMapping::new(0.01).unwrap();
        let xs = [0.001, 0.5, 1.0, 3.7, 1e6];
        let before: Vec<i64> = xs.iter().map(|&x| m.index(x)).collect();
        m.on_collapse();
        for (&x, &i) in xs.iter().zip(&before) {
            assert_eq!(m.index(x), collapsed_index(i), "x={x}");
        }
    }

    #[test]
    fn theorem2_monotone_in_span() {
        let b1 = theorem2_bound(1.0, 1e3, 1024);
        let b2 = theorem2_bound(1.0, 1e9, 1024);
        assert!(b1 < b2);
        assert!(b1 > 0.0 && b2 < 1.0);
    }

    #[test]
    fn invalid_alpha_rejected() {
        assert!(LogMapping::new(0.0).is_err());
        assert!(LogMapping::new(1.0).is_err());
        assert!(LogMapping::new(-0.5).is_err());
        assert!(LogMapping::new(f64::NAN).is_err());
    }

    #[test]
    fn quantile_rank_definition2() {
        // n=10: q=0 -> 1, q=1 -> 10, q=0.5 -> floor(1+4.5)=5
        assert_eq!(quantile_rank(0.0, 10.0), 1.0);
        assert_eq!(quantile_rank(1.0, 10.0), 10.0);
        assert_eq!(quantile_rank(0.5, 10.0), 5.0);
    }
}
