//! UDDSketch — the paper's sequential quantile sketch (§3.2, [11]).
//!
//! DDSketch's logarithmic bucketing with the **uniform collapse**
//! (Algorithm 2): when the summary exceeds `m` buckets every bucket pair
//! `(2j−1, 2j)` fuses into bucket `j` and `γ ← γ²`. Unlike DDSketch's
//! collapse-first-two, the resulting sketch stays α-accurate over the whole
//! quantile range (q₀ = 0, q₁ = 1), with α growing per Lemma 1 and bounded
//! overall by Theorem 2.

use super::{
    quantile_rank, DenseStore, LogMapping, SketchError, Store,
};

/// Sequential UDDSketch over store `S` (default [`DenseStore`]).
///
/// Handles the full real line like DDSketch: positive values map to the
/// positive store, negatives to a mirrored store, zeros to a dedicated
/// counter. Works in the turnstile model ([`UddSketch::delete`]).
///
/// ```
/// use duddsketch::sketch::UddSketch;
/// let mut s: UddSketch = UddSketch::new(0.01, 256).unwrap();
/// for x in [1.0, 2.0, 3.0, 4.0, 5.0] { s.insert(x); }
/// assert!((s.quantile(0.5).unwrap() - 3.0).abs() <= 0.01 * 3.0);
/// ```
#[derive(Debug, Clone)]
pub struct UddSketch<S: Store = DenseStore> {
    mapping: LogMapping,
    max_buckets: usize,
    pos: S,
    neg: S,
    zero_weight: f64,
}

impl<S: Store> UddSketch<S> {
    /// Create a sketch with target accuracy `alpha` and at most
    /// `max_buckets` buckets (the paper's `m`, counted across the positive
    /// and negative stores).
    pub fn new(alpha: f64, max_buckets: usize) -> Result<Self, SketchError> {
        if max_buckets < 2 {
            return Err(SketchError::InvalidBuckets(max_buckets));
        }
        Ok(Self {
            mapping: LogMapping::new(alpha)?,
            max_buckets,
            pos: S::empty(),
            neg: S::empty(),
            zero_weight: 0.0,
        })
    }

    /// Insert one item.
    pub fn insert(&mut self, x: f64) {
        self.update(x, 1.0);
    }

    /// Delete one previously inserted item (turnstile model).
    pub fn delete(&mut self, x: f64) {
        self.update(x, -1.0);
    }

    /// Add weight `w` (possibly negative or fractional) for value `x`.
    pub fn update(&mut self, x: f64, w: f64) {
        assert!(x.is_finite(), "update: non-finite value {x}");
        if x > 0.0 {
            self.pos.add(self.mapping.index(x), w);
        } else if x < 0.0 {
            self.neg.add(self.mapping.index(-x), w);
        } else {
            self.zero_weight += w;
        }
        self.collapse_to_budget();
    }

    /// Insert a slice of items.
    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.insert(x);
        }
    }

    /// Number of non-zero buckets (the paper's `|S|`, across both stores).
    pub fn bucket_count(&self) -> usize {
        self.pos.nonzero() + self.neg.nonzero()
    }

    /// Total inserted weight (stream length for insert-only streams).
    pub fn count(&self) -> f64 {
        self.pos.total() + self.neg.total() + self.zero_weight
    }

    /// True when the sketch holds no weight.
    pub fn is_empty(&self) -> bool {
        self.count() <= 0.0 && self.bucket_count() == 0 && self.zero_weight == 0.0
    }

    /// Current error bound α (≥ the construction-time α after collapses).
    pub fn alpha(&self) -> f64 {
        self.mapping.alpha()
    }

    /// Current γ.
    pub fn gamma(&self) -> f64 {
        self.mapping.gamma()
    }

    /// Number of uniform collapses performed.
    pub fn collapses(&self) -> u32 {
        self.mapping.collapses()
    }

    /// The bucket budget `m`.
    pub fn max_buckets(&self) -> usize {
        self.max_buckets
    }

    /// The index mapping (γ, α, bucket edges).
    pub fn mapping(&self) -> &LogMapping {
        &self.mapping
    }

    /// Read-only positive store.
    pub fn positive_store(&self) -> &S {
        &self.pos
    }

    /// Read-only negative store (indices refer to magnitudes).
    pub fn negative_store(&self) -> &S {
        &self.neg
    }

    /// Weight at zero.
    pub fn zero_weight(&self) -> f64 {
        self.zero_weight
    }

    /// Apply one uniform collapse unconditionally (γ ← γ²).
    pub fn force_collapse(&mut self) {
        self.pos.uniform_collapse();
        self.neg.uniform_collapse();
        self.mapping.on_collapse();
    }

    fn collapse_to_budget(&mut self) {
        while self.bucket_count() > self.max_buckets {
            self.force_collapse();
        }
    }

    /// Collapse until the sketch's γ lineage matches `collapses` rounds
    /// (no-op if already past it).
    pub fn align_to_collapses(&mut self, collapses: u32) {
        while self.mapping.collapses() < collapses {
            self.force_collapse();
        }
    }

    /// Bulk-load raw store contents (wire-format decode path). Entries are
    /// `(logarithmic index, counter)` in the sketch's *current* γ lineage;
    /// the budget is re-enforced afterwards.
    pub fn load_raw(&mut self, zero_weight: f64, pos: &[(i64, f64)], neg: &[(i64, f64)]) {
        self.pos.clear();
        self.neg.clear();
        self.zero_weight = zero_weight;
        for &(i, c) in pos {
            self.pos.add(i, c);
        }
        for &(i, c) in neg {
            self.neg.add(i, c);
        }
        self.collapse_to_budget();
    }

    /// Estimated rank of `x` (Definition 1): the number of summarized
    /// items ≤ x, counting every bucket whose representative is ≤ x.
    pub fn rank(&self, x: f64) -> f64 {
        let mut acc = 0.0;
        let mapping = &self.mapping;
        self.neg.for_each(|i, c| {
            if -mapping.value(i) <= x {
                acc += c;
            }
        });
        if x >= 0.0 {
            acc += self.zero_weight;
        }
        self.pos.for_each(|i, c| {
            if mapping.value(i) <= x {
                acc += c;
            }
        });
        acc
    }

    /// Estimated CDF at `x`: `rank(x) / n`.
    pub fn cdf(&self, x: f64) -> Result<f64, SketchError> {
        let n = self.count();
        if n <= 0.0 {
            return Err(SketchError::Empty);
        }
        Ok((self.rank(x) / n).clamp(0.0, 1.0))
    }

    /// Copy into a sketch backed by a different store type (same mapping,
    /// same counters). The gossip layer keeps [`SparseStore`]-backed peer
    /// states — memory ∝ live buckets, which matters on the adversarial
    /// workload where merged index spans are huge — while bulk local
    /// ingestion uses the faster [`DenseStore`].
    ///
    /// [`SparseStore`]: crate::sketch::SparseStore
    /// [`DenseStore`]: crate::sketch::DenseStore
    pub fn convert_store<T: Store>(&self) -> UddSketch<T> {
        let mut pos = T::empty();
        self.pos.for_each(|i, c| pos.add(i, c));
        let mut neg = T::empty();
        self.neg.for_each(|i, c| neg.add(i, c));
        UddSketch {
            mapping: self.mapping,
            max_buckets: self.max_buckets,
            pos,
            neg,
            zero_weight: self.zero_weight,
        }
    }

    /// Replace the positive store from a dense counter window (used by the
    /// batched gossip executors to write an averaged round back). `counts[k]`
    /// holds the counter of logarithmic index `offset + k`; the mapping
    /// (γ, collapse depth) is left untouched, then the budget is re-enforced.
    pub fn set_positive_dense(&mut self, offset: i64, counts: &[f64]) {
        self.pos.clear();
        for (k, &c) in counts.iter().enumerate() {
            if c != 0.0 {
                self.pos.add(offset + k as i64, c);
            }
        }
        self.collapse_to_budget();
    }

    /// Merge `other` into `self` with weights: counters become
    /// `w_self·self + w_other·other` bucketwise. `(1, 1)` is the standard
    /// mergeability sum; `(0.5, 0.5)` is the gossip averaging of
    /// Algorithm 5.
    ///
    /// Sketches must share the initial α₀; the one with fewer collapses is
    /// collapsed until γ matches (paper §5). The result is re-collapsed to
    /// the bucket budget.
    pub fn merge_weighted(
        &mut self,
        other: &Self,
        w_self: f64,
        w_other: f64,
    ) -> Result<(), SketchError> {
        if !self.mapping.same_lineage(&other.mapping) {
            return Err(SketchError::IncompatibleAlpha(
                self.mapping.alpha0(),
                other.mapping.alpha0(),
            ));
        }
        // Align collapse depth. `other` is logically collapsed by mapping
        // its indices through `collapsed_index` the needed number of times.
        let k_self = self.mapping.collapses();
        let k_other = other.mapping.collapses();
        self.align_to_collapses(k_other);
        let shift = self.mapping.collapses() - k_other;

        self.pos.scale(w_self);
        self.neg.scale(w_self);
        self.zero_weight =
            self.zero_weight * w_self + other.zero_weight * w_other;

        if shift == 0 {
            // Same lineage depth: the store's specialized merge (linear
            // two-pointer for VecStore — the gossip hot path).
            self.pos.merge_scaled(&other.pos, w_other);
            self.neg.merge_scaled(&other.neg, w_other);
        } else {
            let fold = |i: i64| {
                let mut j = i;
                for _ in 0..shift {
                    j = super::collapsed_index(j);
                }
                j
            };
            let pos = &mut self.pos;
            other.pos.for_each(|i, c| pos.add(fold(i), c * w_other));
            let neg = &mut self.neg;
            other.neg.for_each(|i, c| neg.add(fold(i), c * w_other));
        }

        let _ = k_self; // self's depth is subsumed by align_to_collapses
        self.collapse_to_budget();
        Ok(())
    }

    /// Standard merge (Definition 7): `self ← self ⊎ other`.
    pub fn merge(&mut self, other: &Self) -> Result<(), SketchError> {
        self.merge_weighted(other, 1.0, 1.0)
    }

    /// The bucketwise difference `self − old` as a sketch in `self`'s
    /// lineage — defined exactly when `self` is reachable from `old` by
    /// inserts alone (an epoch extending a window, `docs/PROTOCOL.md`
    /// §10): after aligning `old` to `self`'s collapse depth, every
    /// bucket counter and the zero counter of `self` must dominate
    /// `old`'s. Returns `None` when the lineages differ (α₀ or bucket
    /// budget), when `old` has collapsed *further* than `self`, or when
    /// any counter regressed (a window evicted items) — the caller must
    /// fall back to a full reseed.
    ///
    /// Exactness: the uniform collapse is linear (bucket pairs sum), so
    /// aligning `old` up commutes with the subtraction; with
    /// integer-valued counters (unit-weight inserts, which is what the
    /// local epoch summaries hold) every sum and difference below 2⁵³
    /// is exact in f64, so `old ⊎ additive_delta` reproduces `self`
    /// bit-exactly.
    pub fn additive_delta(&self, old: &Self) -> Option<Self> {
        if !self.mapping.same_lineage(&old.mapping)
            || self.max_buckets != old.max_buckets
            || old.mapping.collapses() > self.mapping.collapses()
        {
            return None;
        }
        let mut aligned = old.clone();
        aligned.align_to_collapses(self.mapping.collapses());
        let zero_weight = self.zero_weight - aligned.zero_weight;
        if zero_weight < 0.0 {
            return None;
        }
        fn diff<S: Store>(new: &S, base: &S) -> Option<S> {
            let mut d = S::empty();
            let mut ok = true;
            new.for_each(|i, c| {
                let b = base.get(i);
                if c < b {
                    ok = false;
                } else if c > b {
                    d.add(i, c - b);
                }
            });
            // A bucket present in `base` but gone from (or shrunk in)
            // `new` is a regression; buckets in both were checked above.
            base.for_each(|i, c| {
                if c > new.get(i) {
                    ok = false;
                }
            });
            ok.then_some(d)
        }
        let pos = diff(&self.pos, &aligned.pos)?;
        let neg = diff(&self.neg, &aligned.neg)?;
        Some(UddSketch {
            mapping: self.mapping,
            max_buckets: self.max_buckets,
            pos,
            neg,
            zero_weight,
        })
    }

    /// Estimate the inferior q-quantile (Definition 2) of the summarized
    /// multiset: the estimate is within relative error [`UddSketch::alpha`]
    /// of the true inferior quantile for every q ∈ [0, 1].
    ///
    /// ```
    /// use duddsketch::sketch::UddSketch;
    ///
    /// let mut s: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    /// for i in 1..=10_000 {
    ///     s.insert(i as f64);
    /// }
    /// // 1..=10000 spans more than 1024 buckets at alpha0 = 0.001, so
    /// // uniform collapses ran and the live bound is s.alpha() > 0.001.
    /// let p90 = s.quantile(0.9).unwrap();
    /// assert!((p90 - 9_000.0).abs() <= s.alpha() * 9_000.0 + 1e-9);
    /// assert!(s.quantile(2.0).is_err());
    /// ```
    pub fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        if !(0.0..=1.0).contains(&q) || q.is_nan() {
            return Err(SketchError::InvalidQuantile(q));
        }
        let n = self.count();
        if n <= 0.0 {
            return Err(SketchError::Empty);
        }
        let target = quantile_rank(q, n).max(1.0);
        let mut acc = 0.0;
        let mut result: Option<f64> = None;
        // Negative store: most negative first = descending bucket index.
        let mut neg_entries = self.neg.entries();
        neg_entries.reverse();
        for (i, c) in neg_entries {
            acc += c;
            if acc >= target && result.is_none() {
                result = Some(-self.mapping.value(i));
            }
        }
        if result.is_none() && self.zero_weight > 0.0 {
            acc += self.zero_weight;
            if acc >= target {
                result = Some(0.0);
            }
        }
        if result.is_none() {
            let mapping = &self.mapping;
            self.pos.for_each(|i, c| {
                acc += c;
                if acc >= target && result.is_none() {
                    result = Some(mapping.value(i));
                }
            });
        }
        // Fractional/averaged counters can leave acc slightly below target
        // at the end; clamp to the maximum bucket.
        Ok(result.unwrap_or_else(|| {
            if let Some(i) = self.pos.max_index() {
                self.mapping.value(i)
            } else if self.zero_weight > 0.0 {
                0.0
            } else {
                let i = self.neg.min_index().expect("non-empty sketch");
                -self.mapping.value(i)
            }
        }))
    }

    /// Batch quantile queries.
    pub fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        qs.iter().map(|&q| self.quantile(q)).collect()
    }
}

impl<S: Store> super::QuantileReader for UddSketch<S> {
    fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        UddSketch::quantile(self, q)
    }

    fn cdf(&self, x: f64) -> Result<f64, SketchError> {
        UddSketch::cdf(self, x)
    }

    fn count(&self) -> f64 {
        UddSketch::count(self)
    }

    fn quantiles(&self, qs: &[f64]) -> Result<Vec<f64>, SketchError> {
        UddSketch::quantiles(self, qs)
    }

    fn is_empty(&self) -> bool {
        UddSketch::is_empty(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{default_rng, Rng};
    use crate::sketch::{theorem2_bound, ExactQuantiles, SparseStore};

    const QS: [f64; 11] = [
        0.01, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.99,
    ];

    #[test]
    fn alpha_accuracy_without_collapse() {
        // With a large budget no collapse occurs: every quantile must be
        // within the configured alpha of the exact value.
        let mut r = default_rng(1);
        let xs: Vec<f64> =
            (0..20_000).map(|_| 1.0 + 99.0 * r.next_f64()).collect();
        let mut s: UddSketch = UddSketch::new(0.01, 4096).unwrap();
        s.extend(&xs);
        assert_eq!(s.collapses(), 0);
        let exact = ExactQuantiles::new(&xs);
        for q in QS {
            let est = s.quantile(q).unwrap();
            let tru = exact.quantile(q).unwrap();
            let re = (est - tru).abs() / tru;
            assert!(re <= 0.01 + 1e-9, "q={q} est={est} true={tru} re={re}");
        }
    }

    #[test]
    fn collapse_keeps_theorem2_bound() {
        // Force collapses with a tiny budget; errors stay within the
        // Theorem 2 bound for the observed span.
        let mut r = default_rng(2);
        // Log-uniform over nine decades [1e-3, 1e6] to force collapses.
        let xs: Vec<f64> = (0..50_000)
            .map(|_| 10f64.powf(r.next_f64() * 9.0 - 3.0))
            .collect();
        let mut s: UddSketch = UddSketch::new(0.001, 64).unwrap();
        s.extend(&xs);
        assert!(s.collapses() > 0, "test should exercise collapses");
        assert!(s.bucket_count() <= 64);
        let (mn, mx) = xs
            .iter()
            .fold((f64::MAX, f64::MIN), |(a, b), &x| (a.min(x), b.max(x)));
        let bound = theorem2_bound(mn, mx, 64);
        assert!(s.alpha() <= bound + 1e-9, "alpha {} bound {bound}", s.alpha());
        let exact = ExactQuantiles::new(&xs);
        for q in QS {
            let est = s.quantile(q).unwrap();
            let tru = exact.quantile(q).unwrap();
            let re = (est - tru).abs() / tru;
            assert!(re <= s.alpha() + 1e-9, "q={q} re={re} alpha={}", s.alpha());
        }
    }

    #[test]
    fn count_and_bucket_budget() {
        let mut s: UddSketch = UddSketch::new(0.001, 32).unwrap();
        let mut r = default_rng(3);
        for _ in 0..10_000 {
            s.insert(1.0 + 1e6 * r.next_f64());
        }
        assert_eq!(s.count(), 10_000.0);
        assert!(s.bucket_count() <= 32);
    }

    #[test]
    fn permutation_invariance() {
        // Lemma 1 of [13]: same multiset, any order -> identical sketch.
        let mut r = default_rng(4);
        let xs: Vec<f64> = (0..5_000).map(|_| (10.0 * r.next_f64()).exp()).collect();
        let mut shuffled = xs.clone();
        r.shuffle(&mut shuffled);
        let mut a: UddSketch = UddSketch::new(0.01, 64).unwrap();
        let mut b: UddSketch = UddSketch::new(0.01, 64).unwrap();
        a.extend(&xs);
        b.extend(&shuffled);
        assert_eq!(a.collapses(), b.collapses());
        assert_eq!(a.positive_store().entries(), b.positive_store().entries());
    }

    #[test]
    fn merge_equals_union_processing() {
        // Mergeability (Definition 7): merge(S(D1), S(D2)) == S(D1 ⊎ D2).
        let mut r = default_rng(5);
        let d1: Vec<f64> = (0..3_000).map(|_| 1.0 + r.next_f64() * 50.0).collect();
        let d2: Vec<f64> = (0..7_000).map(|_| 100.0 + r.next_f64() * 1e5).collect();
        let mut s1: UddSketch = UddSketch::new(0.001, 128).unwrap();
        let mut s2: UddSketch = UddSketch::new(0.001, 128).unwrap();
        s1.extend(&d1);
        s2.extend(&d2);
        s1.merge(&s2).unwrap();

        let mut su: UddSketch = UddSketch::new(0.001, 128).unwrap();
        su.extend(&d1);
        su.extend(&d2);

        assert_eq!(s1.count(), 10_000.0);
        assert_eq!(s1.collapses(), su.collapses());
        let e1 = s1.positive_store().entries();
        let eu = su.positive_store().entries();
        assert_eq!(e1.len(), eu.len());
        for ((i1, c1), (iu, cu)) in e1.iter().zip(&eu) {
            assert_eq!(i1, iu);
            assert!((c1 - cu).abs() < 1e-9);
        }
    }

    #[test]
    fn merge_is_commutative() {
        let mut r = default_rng(6);
        let d1: Vec<f64> = (0..2_000).map(|_| 1.0 + r.next_f64() * 1e4).collect();
        let d2: Vec<f64> = (0..2_000).map(|_| 1e-3 + r.next_f64()).collect();
        let build = |d: &[f64]| {
            let mut s: UddSketch = UddSketch::new(0.01, 64).unwrap();
            s.extend(d);
            s
        };
        let mut ab = build(&d1);
        ab.merge(&build(&d2)).unwrap();
        let mut ba = build(&d2);
        ba.merge(&build(&d1)).unwrap();
        for q in QS {
            assert_eq!(ab.quantile(q).unwrap(), ba.quantile(q).unwrap());
        }
    }

    #[test]
    fn merge_rejects_different_alpha0() {
        let a: UddSketch = UddSketch::new(0.01, 64).unwrap();
        let b: UddSketch = UddSketch::new(0.02, 64).unwrap();
        let mut a2 = a.clone();
        assert!(matches!(
            a2.merge(&b),
            Err(SketchError::IncompatibleAlpha(_, _))
        ));
    }

    #[test]
    fn merge_aligns_different_collapse_depths() {
        // s1 is forced to collapse, s2 is not; merge must align lineages
        // and remain exact on counts.
        let mut s1: UddSketch = UddSketch::new(0.001, 16).unwrap();
        let mut s2: UddSketch = UddSketch::new(0.001, 16).unwrap();
        let mut r = default_rng(7);
        for _ in 0..5_000 {
            s1.insert(1e-3 + 1e6 * r.next_f64()); // wide span -> collapses
        }
        for _ in 0..1_000 {
            s2.insert(5.0 + r.next_f64()); // narrow span -> none
        }
        assert!(s1.collapses() > s2.collapses());
        let total = s1.count() + s2.count();
        let mut merged = s2.clone();
        merged.merge(&s1).unwrap();
        assert!((merged.count() - total).abs() < 1e-6);
        assert!(merged.bucket_count() <= 16);
        assert!(merged.collapses() >= s1.collapses());
    }

    #[test]
    fn turnstile_delete_restores_state() {
        let mut s: UddSketch = UddSketch::new(0.01, 128).unwrap();
        s.insert(10.0);
        s.insert(20.0);
        s.insert(30.0);
        let before = s.positive_store().entries();
        s.insert(400.0);
        s.delete(400.0);
        assert_eq!(s.positive_store().entries(), before);
        assert_eq!(s.count(), 3.0);
    }

    #[test]
    fn negative_and_zero_values() {
        let mut s: UddSketch = UddSketch::new(0.01, 128).unwrap();
        for x in [-100.0, -10.0, -1.0, 0.0, 1.0, 10.0, 100.0] {
            s.insert(x);
        }
        assert_eq!(s.count(), 7.0);
        let med = s.quantile(0.5).unwrap();
        assert_eq!(med, 0.0);
        let lo = s.quantile(0.0).unwrap();
        assert!((lo + 100.0).abs() <= 1.0, "min est {lo}");
        let hi = s.quantile(1.0).unwrap();
        assert!((hi - 100.0).abs() <= 1.0, "max est {hi}");
    }

    #[test]
    fn quantile_edge_cases() {
        let mut s: UddSketch = UddSketch::new(0.01, 64).unwrap();
        assert_eq!(s.quantile(0.5), Err(SketchError::Empty));
        s.insert(42.0);
        for q in [0.0, 0.5, 1.0] {
            let est = s.quantile(q).unwrap();
            assert!((est - 42.0).abs() <= 0.01 * 42.0);
        }
        assert!(matches!(
            s.quantile(1.5),
            Err(SketchError::InvalidQuantile(_))
        ));
        assert!(matches!(
            s.quantile(f64::NAN),
            Err(SketchError::InvalidQuantile(_))
        ));
    }

    #[test]
    fn sparse_store_variant_agrees() {
        let mut r = default_rng(8);
        let xs: Vec<f64> = (0..10_000).map(|_| (8.0 * r.next_f64()).exp()).collect();
        let mut d: UddSketch<DenseStore> = UddSketch::new(0.005, 64).unwrap();
        let mut sp: UddSketch<SparseStore> = UddSketch::new(0.005, 64).unwrap();
        d.extend(&xs);
        sp.extend(&xs);
        assert_eq!(d.collapses(), sp.collapses());
        for q in QS {
            assert_eq!(d.quantile(q).unwrap(), sp.quantile(q).unwrap());
        }
    }

    #[test]
    fn rank_and_cdf() {
        let mut s: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        for i in 1..=1000 {
            s.insert(i as f64);
        }
        // rank within alpha-blur of truth: value x=500 has true rank 500.
        let r = s.rank(500.0);
        assert!((r - 500.0).abs() <= 2.0, "rank {r}");
        assert_eq!(s.rank(0.5), 0.0);
        assert_eq!(s.rank(2000.0), 1000.0);
        let c = s.cdf(250.0).unwrap();
        assert!((c - 0.25).abs() < 0.01, "cdf {c}");
        // CDF is monotone.
        let mut prev = 0.0;
        for x in [1.0, 10.0, 100.0, 500.0, 999.0] {
            let c = s.cdf(x).unwrap();
            assert!(c >= prev);
            prev = c;
        }
    }

    /// Epoch-carry algebra: `old ⊎ additive_delta(new, old)` rebuilds
    /// `new` bit-exactly, including across a collapse-depth gap.
    #[test]
    fn additive_delta_roundtrips_bit_exact() {
        let mut r = default_rng(9);
        let mut old: UddSketch = UddSketch::new(0.001, 64).unwrap();
        for _ in 0..5_000 {
            old.insert(10f64.powf(r.next_f64() * 6.0 - 3.0));
        }
        let mut new = old.clone();
        for _ in 0..5_000 {
            // A wider span than old's: the extension forces extra
            // collapses, exercising the alignment path.
            new.insert(10f64.powf(r.next_f64() * 9.0 - 3.0));
        }
        new.insert(0.0);
        new.insert(-3.5);
        // Guarantee a collapse-depth gap regardless of the sampled
        // spans: the delta must re-fold `old` up to `new`'s depth.
        new.force_collapse();
        assert!(new.collapses() > old.collapses());

        let delta = new.additive_delta(&old).expect("insert-only extension");
        assert_eq!(delta.collapses(), new.collapses());
        assert_eq!(delta.count(), new.count() - old.count());

        let mut rebuilt = old.clone();
        rebuilt.merge(&delta).unwrap();
        assert_eq!(rebuilt.collapses(), new.collapses());
        assert_eq!(rebuilt.zero_weight(), new.zero_weight());
        assert_eq!(
            rebuilt.positive_store().entries(),
            new.positive_store().entries()
        );
        assert_eq!(
            rebuilt.negative_store().entries(),
            new.negative_store().entries()
        );
    }

    #[test]
    fn additive_delta_rejects_non_extensions() {
        let mut old: UddSketch = UddSketch::new(0.01, 64).unwrap();
        old.extend(&[1.0, 2.0, 3.0]);

        // A window eviction (turnstile delete) regresses a bucket.
        let mut evicted = old.clone();
        evicted.delete(2.0);
        evicted.insert(50.0);
        assert!(evicted.additive_delta(&old).is_none());

        // A dropped zero counter regresses too.
        let mut z = old.clone();
        z.insert(0.0);
        assert!(old.additive_delta(&z).is_none());

        // Different α₀ lineage.
        let other: UddSketch = UddSketch::new(0.02, 64).unwrap();
        assert!(other.additive_delta(&old).is_none());

        // `old` collapsed past `new`: the subtraction is undefined.
        let mut deeper = old.clone();
        deeper.force_collapse();
        assert!(old.additive_delta(&deeper).is_none());

        // Identity extension: an all-zero delta, still mergeable.
        let delta = old.additive_delta(&old).expect("x − x is defined");
        assert_eq!(delta.count(), 0.0);
        assert!(delta.is_empty());
    }

    #[test]
    fn weighted_merge_halves_counts() {
        // Gossip-style averaging: (0.5, 0.5) preserves bucket support and
        // halves the total.
        let mut a: UddSketch = UddSketch::new(0.01, 64).unwrap();
        let mut b: UddSketch = UddSketch::new(0.01, 64).unwrap();
        a.insert(10.0);
        a.insert(10.0);
        b.insert(10.0);
        let mut avg = a.clone();
        avg.merge_weighted(&b, 0.5, 0.5).unwrap();
        assert!((avg.count() - 1.5).abs() < 1e-12);
        let i = avg.mapping().index(10.0);
        assert!((avg.positive_store().get(i) - 1.5).abs() < 1e-12);
    }
}
