//! Bucket stores backing the sketches.
//!
//! A store maps logarithmic bucket indices `i ∈ ℤ` to real-valued counters
//! (fractional under gossip averaging, negative transiently in the
//! turnstile model). Two implementations are provided and ablated in
//! `benches/ablation_collapse.rs`:
//!
//! * [`DenseStore`] — contiguous `Vec<f64>` window with an index offset;
//!   O(1) insert, cache-friendly scans. The default and the hot-path
//!   choice.
//! * [`SparseStore`] — `BTreeMap<i64, f64>`; compact for pathological index
//!   spans (e.g. inputs straddling hundreds of orders of magnitude).

use std::collections::BTreeMap;

/// Counter storage for logarithmic bucket indices.
pub trait Store: Clone + std::fmt::Debug {
    /// Create an empty store.
    fn empty() -> Self;

    /// Add weight `w` (may be negative — turnstile model) to bucket `i`.
    /// Counters that reach exactly zero are dropped.
    fn add(&mut self, i: i64, w: f64);

    /// Counter value at `i` (0.0 when absent).
    fn get(&self, i: i64) -> f64;

    /// Total weight across buckets.
    fn total(&self) -> f64;

    /// Number of buckets with non-zero counters (the paper's `|S|`).
    fn nonzero(&self) -> usize;

    /// Smallest index with a non-zero counter.
    fn min_index(&self) -> Option<i64>;

    /// Largest index with a non-zero counter.
    fn max_index(&self) -> Option<i64>;

    /// Visit `(index, counter)` for non-zero buckets in ascending index
    /// order.
    fn for_each(&self, f: impl FnMut(i64, f64));

    /// Uniform collapse (Algorithm 2): every bucket `i` moves to
    /// `⌈i/2⌉`; pairs `(2j−1, 2j)` fuse into `j`.
    fn uniform_collapse(&mut self);

    /// Collapse the two lowest non-zero buckets into the higher of the two
    /// (DDSketch's strategy, Algorithm 1).
    fn collapse_lowest_pair(&mut self);

    /// Multiply every counter by `f` (gossip averaging support).
    /// `f = 0` clears the store.
    fn scale(&mut self, f: f64);

    /// Remove all buckets.
    fn clear(&mut self);

    /// Merge `other`'s counters scaled by `w` into `self`
    /// (`self[i] += w * other[i]`). Stores may specialize this (the
    /// gossip hot path — [`VecStore`] does a linear two-pointer merge).
    fn merge_scaled(&mut self, other: &Self, w: f64) {
        other.for_each(|i, c| self.add(i, c * w));
    }

    /// Non-zero entries in ascending index order (convenience).
    fn entries(&self) -> Vec<(i64, f64)> {
        let mut out = Vec::with_capacity(self.nonzero());
        self.for_each(|i, c| out.push((i, c)));
        out
    }

    /// True when no bucket holds weight.
    fn is_empty(&self) -> bool {
        self.nonzero() == 0
    }
}

/// Ceiling of `i/2` over integers (uniform-collapse index map, Lemma 1).
#[inline]
pub fn collapsed_index(i: i64) -> i64 {
    (i + 1).div_euclid(2)
}

// ---------------------------------------------------------------------------
// DenseStore
// ---------------------------------------------------------------------------

/// Contiguous window store: `counts[k]` is the counter of index
/// `offset + k`. The window grows geometrically on demand and re-anchors on
/// collapse.
#[derive(Debug, Clone, Default)]
pub struct DenseStore {
    counts: Vec<f64>,
    offset: i64,
    nonzero: usize,
    total: f64,
    /// Deletes that zeroed a bucket since the last compaction check;
    /// every [`COMPACT_CHECK_PERIOD`] such events the window is
    /// re-anchored if the live span occupies a small fraction of it.
    shrink_ticks: usize,
}

/// Freed-bucket events between automatic compaction checks (amortizes the
/// O(window) span scan).
const COMPACT_CHECK_PERIOD: usize = 64;

/// Windows smaller than this are never worth re-anchoring.
const COMPACT_MIN_LEN: usize = 64;

impl DenseStore {
    fn slot(&self, i: i64) -> Option<usize> {
        let k = i - self.offset;
        if k >= 0 && (k as usize) < self.counts.len() {
            Some(k as usize)
        } else {
            None
        }
    }

    /// Grow the window so that index `i` is addressable.
    fn ensure(&mut self, i: i64) -> usize {
        if self.counts.is_empty() {
            // Anchor the window at i with a little slack on both sides.
            self.offset = i - 4;
            self.counts = vec![0.0; 16];
            return (i - self.offset) as usize;
        }
        if i < self.offset {
            // Prepend, growing at least 2x to amortize.
            let needed = (self.offset - i) as usize;
            let grow = needed.max(self.counts.len());
            let mut next = vec![0.0; grow + self.counts.len()];
            next[grow..].copy_from_slice(&self.counts);
            self.counts = next;
            self.offset -= grow as i64;
        }
        let k = (i - self.offset) as usize;
        if k >= self.counts.len() {
            let target = (k + 1).max(self.counts.len() * 2);
            self.counts.resize(target, 0.0);
        }
        k
    }

    /// Direct read-only view `(offset, counts)` for the dense gossip path.
    pub fn raw(&self) -> (i64, &[f64]) {
        (self.offset, &self.counts)
    }

    #[inline]
    fn note_freed_bucket(&mut self) {
        self.shrink_ticks += 1;
        if self.shrink_ticks >= COMPACT_CHECK_PERIOD {
            self.shrink_ticks = 0;
            self.compact();
        }
    }

    /// Re-anchor the contiguous window onto the live index span when the
    /// allocation has grown far past it. Long-lived turnstile shards
    /// (service ingest, churn rejoin) would otherwise hold a
    /// monotonically grown `Vec<f64>` after collapses/deletes drive the
    /// edge buckets to zero. No-op while the window is small or at least
    /// a quarter full; runs automatically every
    /// [`COMPACT_CHECK_PERIOD`] freed buckets.
    pub fn compact(&mut self) {
        if self.counts.len() < COMPACT_MIN_LEN {
            return;
        }
        if self.nonzero == 0 {
            self.counts = Vec::new();
            self.offset = 0;
            return;
        }
        let lo = self.min_index().expect("nonzero > 0");
        let hi = self.max_index().expect("nonzero > 0");
        let span = (hi - lo + 1) as usize;
        if self.counts.len() < 4 * span + 16 {
            return;
        }
        let mut next = vec![0.0; span + 8];
        for (k, slot) in next[4..4 + span].iter_mut().enumerate() {
            *slot = self.get(lo + k as i64);
        }
        self.counts = next;
        self.offset = lo - 4;
    }
}

impl Store for DenseStore {
    fn empty() -> Self {
        Self::default()
    }

    #[inline]
    fn add(&mut self, i: i64, w: f64) {
        if w == 0.0 {
            return;
        }
        let k = match self.slot(i) {
            Some(k) => k,
            None => self.ensure(i),
        };
        let before = self.counts[k];
        let after = before + w;
        // Treat tiny residues from float cancellation as zero so turnstile
        // deletes actually free buckets.
        let after = if after.abs() < 1e-12 { 0.0 } else { after };
        self.counts[k] = after;
        self.total += after - before;
        match (before != 0.0, after != 0.0) {
            (false, true) => self.nonzero += 1,
            (true, false) => {
                self.nonzero -= 1;
                self.note_freed_bucket();
            }
            _ => {}
        }
    }

    #[inline]
    fn get(&self, i: i64) -> f64 {
        self.slot(i).map_or(0.0, |k| self.counts[k])
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn nonzero(&self) -> usize {
        self.nonzero
    }

    fn min_index(&self) -> Option<i64> {
        self.counts
            .iter()
            .position(|&c| c != 0.0)
            .map(|k| self.offset + k as i64)
    }

    fn max_index(&self) -> Option<i64> {
        self.counts
            .iter()
            .rposition(|&c| c != 0.0)
            .map(|k| self.offset + k as i64)
    }

    fn for_each(&self, mut f: impl FnMut(i64, f64)) {
        for (k, &c) in self.counts.iter().enumerate() {
            if c != 0.0 {
                f(self.offset + k as i64, c);
            }
        }
    }

    fn uniform_collapse(&mut self) {
        if self.nonzero == 0 {
            return;
        }
        let lo = self.min_index().unwrap();
        let hi = self.max_index().unwrap();
        let new_lo = collapsed_index(lo);
        let new_hi = collapsed_index(hi);
        let mut next = vec![0.0; (new_hi - new_lo + 1) as usize + 8];
        let next_offset = new_lo - 4;
        let mut nonzero = 0usize;
        self.for_each(|i, c| {
            let j = collapsed_index(i);
            let k = (j - next_offset) as usize;
            if next[k] == 0.0 {
                nonzero += 1;
            }
            next[k] += c;
            if next[k] == 0.0 {
                nonzero -= 1; // exact cancellation (negative weights)
            }
        });
        self.counts = next;
        self.offset = next_offset;
        self.nonzero = nonzero;
        // total unchanged by construction
    }

    fn scale(&mut self, f: f64) {
        if f == 0.0 {
            self.clear();
            return;
        }
        for c in &mut self.counts {
            *c *= f;
        }
        self.total *= f;
    }

    fn collapse_lowest_pair(&mut self) {
        if self.nonzero < 2 {
            return;
        }
        let lo = self.min_index().unwrap();
        let c = self.get(lo);
        // Find the next non-zero above lo.
        let mut next_i = None;
        let start = (lo - self.offset) as usize + 1;
        for k in start..self.counts.len() {
            if self.counts[k] != 0.0 {
                next_i = Some(self.offset + k as i64);
                break;
            }
        }
        let z = next_i.expect("nonzero >= 2 guarantees a second bucket");
        self.add(lo, -c);
        self.add(z, c);
    }

    fn clear(&mut self) {
        self.counts.clear();
        self.offset = 0;
        self.nonzero = 0;
        self.total = 0.0;
        self.shrink_ticks = 0;
    }
}

// ---------------------------------------------------------------------------
// SparseStore
// ---------------------------------------------------------------------------

/// Ordered-map store; memory proportional to the number of live buckets.
#[derive(Debug, Clone, Default)]
pub struct SparseStore {
    map: BTreeMap<i64, f64>,
    total: f64,
}

impl Store for SparseStore {
    fn empty() -> Self {
        Self::default()
    }

    #[inline]
    fn add(&mut self, i: i64, w: f64) {
        if w == 0.0 {
            return;
        }
        self.total += w;
        let e = self.map.entry(i).or_insert(0.0);
        *e += w;
        if e.abs() < 1e-12 {
            self.total -= *e;
            self.map.remove(&i);
        }
    }

    #[inline]
    fn get(&self, i: i64) -> f64 {
        self.map.get(&i).copied().unwrap_or(0.0)
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn nonzero(&self) -> usize {
        self.map.len()
    }

    fn min_index(&self) -> Option<i64> {
        self.map.keys().next().copied()
    }

    fn max_index(&self) -> Option<i64> {
        self.map.keys().next_back().copied()
    }

    fn for_each(&self, mut f: impl FnMut(i64, f64)) {
        for (&i, &c) in &self.map {
            f(i, c);
        }
    }

    fn uniform_collapse(&mut self) {
        let mut next = BTreeMap::new();
        for (&i, &c) in &self.map {
            *next.entry(collapsed_index(i)).or_insert(0.0) += c;
        }
        next.retain(|_, c: &mut f64| *c != 0.0);
        self.map = next;
    }

    fn scale(&mut self, f: f64) {
        if f == 0.0 {
            self.clear();
            return;
        }
        for c in self.map.values_mut() {
            *c *= f;
        }
        self.total *= f;
    }

    fn collapse_lowest_pair(&mut self) {
        if self.map.len() < 2 {
            return;
        }
        let (&lo, &c) = self.map.iter().next().unwrap();
        let (&z, _) = self.map.iter().nth(1).unwrap();
        self.map.remove(&lo);
        *self.map.entry(z).or_insert(0.0) += c;
    }

    fn clear(&mut self) {
        self.map.clear();
        self.total = 0.0;
    }
}

// ---------------------------------------------------------------------------
// VecStore
// ---------------------------------------------------------------------------

/// Sorted-vector store: entries `(index, counter)` kept in ascending index
/// order. The gossip hot-path representation — bucket merges become linear
/// two-pointer merges over contiguous memory (see `merge_scaled`), clones
/// are single memcpys, and uniform collapse is one in-place pass. Point
/// inserts are O(m) worst case, so bulk ingestion still uses
/// [`DenseStore`] and converts once.
#[derive(Debug, Clone, Default)]
pub struct VecStore {
    entries: Vec<(i64, f64)>,
    total: f64,
}

impl VecStore {
    #[inline]
    fn drop_if_zero(&mut self, pos: usize) {
        if self.entries[pos].1.abs() < 1e-12 {
            self.total -= self.entries[pos].1;
            self.entries.remove(pos);
        }
    }
}

impl Store for VecStore {
    fn empty() -> Self {
        Self::default()
    }

    #[inline]
    fn add(&mut self, i: i64, w: f64) {
        if w == 0.0 {
            return;
        }
        self.total += w;
        // Fast path: append in ascending order (dense write-back, decode).
        if self.entries.last().map_or(true, |&(j, _)| j < i) {
            self.entries.push((i, w));
            self.drop_if_zero(self.entries.len() - 1);
            return;
        }
        match self.entries.binary_search_by_key(&i, |&(j, _)| j) {
            Ok(pos) => {
                self.entries[pos].1 += w;
                self.drop_if_zero(pos);
            }
            Err(pos) => self.entries.insert(pos, (i, w)),
        }
    }

    #[inline]
    fn get(&self, i: i64) -> f64 {
        self.entries
            .binary_search_by_key(&i, |&(j, _)| j)
            .map(|pos| self.entries[pos].1)
            .unwrap_or(0.0)
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn nonzero(&self) -> usize {
        self.entries.len()
    }

    fn min_index(&self) -> Option<i64> {
        self.entries.first().map(|&(i, _)| i)
    }

    fn max_index(&self) -> Option<i64> {
        self.entries.last().map(|&(i, _)| i)
    }

    fn for_each(&self, mut f: impl FnMut(i64, f64)) {
        for &(i, c) in &self.entries {
            f(i, c);
        }
    }

    fn uniform_collapse(&mut self) {
        // ceil(i/2) is monotone, so one in-place pass keeps the order.
        let mut out = 0usize;
        for k in 0..self.entries.len() {
            let (i, c) = self.entries[k];
            let j = collapsed_index(i);
            if out > 0 && self.entries[out - 1].0 == j {
                self.entries[out - 1].1 += c;
                if self.entries[out - 1].1 == 0.0 {
                    out -= 1; // exact cancellation under negative weights
                }
            } else {
                self.entries[out] = (j, c);
                out += 1;
            }
        }
        self.entries.truncate(out);
    }

    fn collapse_lowest_pair(&mut self) {
        if self.entries.len() < 2 {
            return;
        }
        let (_, c) = self.entries.remove(0);
        self.entries[0].1 += c;
    }

    fn scale(&mut self, f: f64) {
        if f == 0.0 {
            self.clear();
            return;
        }
        for e in &mut self.entries {
            e.1 *= f;
        }
        self.total *= f;
    }

    fn merge_scaled(&mut self, other: &Self, w: f64) {
        if other.entries.is_empty() || w == 0.0 {
            return;
        }
        // Linear two-pointer merge of two sorted entry lists.
        let mut out = Vec::with_capacity(self.entries.len() + other.entries.len());
        let (a, b) = (&self.entries, &other.entries);
        let (mut x, mut y) = (0usize, 0usize);
        while x < a.len() && y < b.len() {
            match a[x].0.cmp(&b[y].0) {
                std::cmp::Ordering::Less => {
                    out.push(a[x]);
                    x += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push((b[y].0, b[y].1 * w));
                    y += 1;
                }
                std::cmp::Ordering::Equal => {
                    let c = a[x].1 + b[y].1 * w;
                    if c.abs() >= 1e-12 {
                        out.push((a[x].0, c));
                    }
                    x += 1;
                    y += 1;
                }
            }
        }
        out.extend_from_slice(&a[x..]);
        out.extend(b[y..].iter().map(|&(i, c)| (i, c * w)));
        self.entries = out;
        self.total += other.total * w;
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.total = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collapsed_index_matches_ceil_halving() {
        for i in -20i64..=20 {
            let expect = (i as f64 / 2.0).ceil() as i64;
            assert_eq!(collapsed_index(i), expect, "i={i}");
        }
    }

    fn exercise<S: Store>() {
        let mut s = S::empty();
        assert!(s.is_empty());
        assert_eq!(s.min_index(), None);
        s.add(5, 2.0);
        s.add(-3, 1.0);
        s.add(100, 4.0);
        assert_eq!(s.nonzero(), 3);
        assert_eq!(s.total(), 7.0);
        assert_eq!(s.min_index(), Some(-3));
        assert_eq!(s.max_index(), Some(100));
        assert_eq!(s.get(5), 2.0);
        assert_eq!(s.get(6), 0.0);
        // Turnstile: deleting to zero frees the bucket.
        s.add(5, -2.0);
        assert_eq!(s.nonzero(), 2);
        assert_eq!(s.get(5), 0.0);
        // Entries ascend.
        let e = s.entries();
        assert_eq!(e, vec![(-3, 1.0), (100, 4.0)]);
    }

    #[test]
    fn dense_basic() {
        exercise::<DenseStore>();
    }

    #[test]
    fn sparse_basic() {
        exercise::<SparseStore>();
    }

    #[test]
    fn vec_basic() {
        exercise::<VecStore>();
    }

    #[test]
    fn vec_uniform_collapse() {
        exercise_uniform_collapse::<VecStore>();
    }

    #[test]
    fn vec_collapse_negative() {
        exercise_collapse_negative_indices::<VecStore>();
    }

    #[test]
    fn vec_lowest_pair() {
        exercise_lowest_pair::<VecStore>();
    }

    #[test]
    fn vec_merge_scaled_matches_default() {
        use crate::rng::{default_rng, Rng};
        let mut r = default_rng(123);
        for _ in 0..50 {
            let mut a = VecStore::empty();
            let mut b = VecStore::empty();
            let mut sp_a = SparseStore::empty();
            let mut sp_b = SparseStore::empty();
            for _ in 0..200 {
                let i = r.next_below(80) as i64 - 40;
                let w = 1.0 + r.next_f64();
                if r.chance(0.5) {
                    a.add(i, w);
                    sp_a.add(i, w);
                } else {
                    b.add(i, w);
                    sp_b.add(i, w);
                }
            }
            let w = 0.5;
            a.merge_scaled(&b, w);
            sp_a.merge_scaled(&sp_b, w); // default trait impl
            let ea = a.entries();
            let eb = sp_a.entries();
            assert_eq!(ea.len(), eb.len());
            for ((i, c), (j, d)) in ea.iter().zip(&eb) {
                assert_eq!(i, j);
                assert!((c - d).abs() < 1e-12);
            }
            assert!((a.total() - sp_a.total()).abs() < 1e-9);
        }
    }

    #[test]
    fn vec_merge_scaled_exact_cancellation() {
        let mut a = VecStore::empty();
        a.add(5, 2.0);
        a.add(7, 1.0);
        let mut b = VecStore::empty();
        b.add(5, -4.0);
        a.merge_scaled(&b, 0.5); // 2.0 + 0.5*(-4.0) = 0 -> bucket freed
        assert_eq!(a.entries(), vec![(7, 1.0)]);
    }

    fn exercise_uniform_collapse<S: Store>() {
        let mut s = S::empty();
        // indices 1..=8, counter = index value for traceability
        for i in 1..=8i64 {
            s.add(i, i as f64);
        }
        let before = s.total();
        s.uniform_collapse();
        assert_eq!(s.total(), before);
        // (1,2)->1, (3,4)->2, (5,6)->3, (7,8)->4
        assert_eq!(
            s.entries(),
            vec![(1, 3.0), (2, 7.0), (3, 11.0), (4, 15.0)]
        );
    }

    #[test]
    fn dense_uniform_collapse() {
        exercise_uniform_collapse::<DenseStore>();
    }

    #[test]
    fn sparse_uniform_collapse() {
        exercise_uniform_collapse::<SparseStore>();
    }

    fn exercise_collapse_negative_indices<S: Store>() {
        let mut s = S::empty();
        s.add(-5, 1.0);
        s.add(-4, 2.0);
        s.add(0, 3.0);
        s.uniform_collapse();
        // -5 -> -2, -4 -> -2, 0 -> 0
        assert_eq!(s.entries(), vec![(-2, 3.0), (0, 3.0)]);
    }

    #[test]
    fn dense_collapse_negative() {
        exercise_collapse_negative_indices::<DenseStore>();
    }

    #[test]
    fn sparse_collapse_negative() {
        exercise_collapse_negative_indices::<SparseStore>();
    }

    fn exercise_lowest_pair<S: Store>() {
        let mut s = S::empty();
        s.add(2, 5.0);
        s.add(7, 1.0);
        s.add(9, 2.0);
        s.collapse_lowest_pair();
        assert_eq!(s.entries(), vec![(7, 6.0), (9, 2.0)]);
        assert_eq!(s.total(), 8.0);
    }

    #[test]
    fn dense_lowest_pair() {
        exercise_lowest_pair::<DenseStore>();
    }

    #[test]
    fn sparse_lowest_pair() {
        exercise_lowest_pair::<SparseStore>();
    }

    #[test]
    fn dense_window_growth_both_directions() {
        let mut s = DenseStore::empty();
        s.add(0, 1.0);
        s.add(1000, 1.0);
        s.add(-1000, 1.0);
        assert_eq!(s.nonzero(), 3);
        assert_eq!(s.min_index(), Some(-1000));
        assert_eq!(s.max_index(), Some(1000));
        assert_eq!(s.get(0), 1.0);
    }

    #[test]
    fn dense_compact_reanchors_window() {
        let mut s = DenseStore::empty();
        for i in 0..4096i64 {
            s.add(i, 1.0);
        }
        let grown = s.raw().1.len();
        assert!(grown >= 4096);
        for i in 0..4096i64 {
            if !(2000..2010).contains(&i) {
                s.add(i, -1.0);
            }
        }
        assert_eq!(s.nonzero(), 10);
        s.compact();
        let (offset, counts) = s.raw();
        assert!(
            counts.len() <= 10 + 8,
            "window not re-anchored: len {}",
            counts.len()
        );
        assert!(offset <= 2000);
        for i in 2000..2010i64 {
            assert_eq!(s.get(i), 1.0);
        }
        assert_eq!(s.total(), 10.0);
        assert_eq!(s.min_index(), Some(2000));
        assert_eq!(s.max_index(), Some(2009));
        // Still fully usable after re-anchoring.
        s.add(-500, 2.0);
        s.add(9000, 3.0);
        assert_eq!(s.nonzero(), 12);
        assert_eq!(s.total(), 15.0);
    }

    #[test]
    fn dense_compacts_automatically_under_turnstile_churn() {
        let mut s = DenseStore::empty();
        for i in 0..4096i64 {
            s.add(i, 1.0);
        }
        let grown = s.raw().1.len();
        // Retire the stream from the top down (sliding-low-watermark
        // pattern); the periodic check must shrink the allocation without
        // any explicit compact() call.
        for i in (64..4096i64).rev() {
            s.add(i, -1.0);
        }
        assert_eq!(s.nonzero(), 64);
        let len = s.raw().1.len();
        assert!(
            len < grown / 4,
            "automatic compaction missing: len {len} vs grown {grown}"
        );
        assert_eq!(s.total(), 64.0);
        assert_eq!(s.entries().len(), 64);
    }

    #[test]
    fn dense_compact_on_empty_store_resets_allocation() {
        let mut s = DenseStore::empty();
        for i in 0..1024i64 {
            s.add(i, 1.0);
        }
        for i in 0..1024i64 {
            s.add(i, -1.0);
        }
        s.compact();
        assert_eq!(s.raw().1.len(), 0);
        assert!(s.is_empty());
        s.add(7, 1.0);
        assert_eq!(s.get(7), 1.0);
    }

    #[test]
    fn stores_agree_randomized() {
        use crate::rng::{default_rng, Rng};
        let mut r = default_rng(99);
        let mut d = DenseStore::empty();
        let mut sp = SparseStore::empty();
        for _ in 0..5000 {
            let i = r.next_below(200) as i64 - 100;
            let w = if r.chance(0.2) { -1.0 } else { 1.0 };
            d.add(i, w);
            sp.add(i, w);
        }
        for _ in 0..3 {
            assert_eq!(d.entries(), sp.entries());
            assert!((d.total() - sp.total()).abs() < 1e-9);
            assert_eq!(d.nonzero(), sp.nonzero());
            d.uniform_collapse();
            sp.uniform_collapse();
        }
    }
}
