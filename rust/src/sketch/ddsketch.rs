//! DDSketch — the predecessor baseline (§3.1, [17]).
//!
//! Identical logarithmic bucketing, but when the budget is exceeded the
//! **two lowest buckets collapse into one** (Algorithm 1). γ never changes,
//! so high quantiles keep the initial α forever while low quantiles can
//! degrade arbitrarily (Proposition 1) — exactly the weakness UDDSketch's
//! uniform collapse removes. Implemented for the accuracy ablation
//! (`benches/ablation_collapse.rs`).

use super::{quantile_rank, DenseStore, LogMapping, SketchError, Store};

/// Sequential DDSketch over store `S` (positive values only, matching the
/// original paper's primary store; the experiments' inputs are ℝ>0).
#[derive(Debug, Clone)]
pub struct DdSketch<S: Store = DenseStore> {
    mapping: LogMapping,
    max_buckets: usize,
    store: S,
}

impl<S: Store> DdSketch<S> {
    /// Create a sketch with accuracy `alpha` and at most `max_buckets`
    /// buckets.
    pub fn new(alpha: f64, max_buckets: usize) -> Result<Self, SketchError> {
        if max_buckets < 2 {
            return Err(SketchError::InvalidBuckets(max_buckets));
        }
        Ok(Self {
            mapping: LogMapping::new(alpha)?,
            max_buckets,
            store: S::empty(),
        })
    }

    /// Insert a positive value.
    pub fn insert(&mut self, x: f64) {
        self.update(x, 1.0);
    }

    /// Remove a previously inserted value.
    pub fn delete(&mut self, x: f64) {
        self.update(x, -1.0);
    }

    /// Add weight `w` for value `x > 0`.
    pub fn update(&mut self, x: f64, w: f64) {
        assert!(x > 0.0 && x.is_finite(), "DdSketch supports x > 0, got {x}");
        self.store.add(self.mapping.index(x), w);
        while self.store.nonzero() > self.max_buckets {
            self.store.collapse_lowest_pair();
        }
    }

    /// Insert a slice.
    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.insert(x);
        }
    }

    /// Total weight.
    pub fn count(&self) -> f64 {
        self.store.total()
    }

    /// Non-zero buckets.
    pub fn bucket_count(&self) -> usize {
        self.store.nonzero()
    }

    /// The (constant) error parameter α.
    pub fn alpha(&self) -> f64 {
        self.mapping.alpha()
    }

    /// The index mapping.
    pub fn mapping(&self) -> &LogMapping {
        &self.mapping
    }

    /// Estimate the inferior q-quantile.
    pub fn quantile(&self, q: f64) -> Result<f64, SketchError> {
        if !(0.0..=1.0).contains(&q) || q.is_nan() {
            return Err(SketchError::InvalidQuantile(q));
        }
        let n = self.count();
        if n <= 0.0 {
            return Err(SketchError::Empty);
        }
        let target = quantile_rank(q, n).max(1.0);
        let mut acc = 0.0;
        let mut result = None;
        let mapping = &self.mapping;
        self.store.for_each(|i, c| {
            acc += c;
            if acc >= target && result.is_none() {
                result = Some(mapping.value(i));
            }
        });
        Ok(result.unwrap_or_else(|| {
            mapping.value(self.store.max_index().expect("non-empty"))
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{default_rng, Rng};
    use crate::sketch::{ExactQuantiles, UddSketch};

    #[test]
    fn accurate_when_no_collapse() {
        let mut r = default_rng(1);
        let xs: Vec<f64> = (0..10_000).map(|_| 1.0 + 99.0 * r.next_f64()).collect();
        let mut s: DdSketch = DdSketch::new(0.01, 4096).unwrap();
        s.extend(&xs);
        let exact = ExactQuantiles::new(&xs);
        for q in [0.01, 0.25, 0.5, 0.75, 0.99] {
            let est = s.quantile(q).unwrap();
            let tru = exact.quantile(q).unwrap();
            assert!((est - tru).abs() / tru <= 0.01 + 1e-9);
        }
    }

    #[test]
    fn high_quantiles_survive_collapse_low_quantiles_degrade() {
        // The documented DDSketch failure mode: with a small budget over a
        // wide span, q->1 stays alpha-accurate but q->0 does not.
        let mut r = default_rng(2);
        let xs: Vec<f64> = (0..50_000)
            .map(|_| 10f64.powf(r.next_f64() * 8.0 - 2.0))
            .collect();
        let mut s: DdSketch = DdSketch::new(0.01, 32).unwrap();
        s.extend(&xs);
        assert!(s.bucket_count() <= 32);
        let exact = ExactQuantiles::new(&xs);
        let est99 = s.quantile(0.99).unwrap();
        let tru99 = exact.quantile(0.99).unwrap();
        assert!(
            (est99 - tru99).abs() / tru99 <= 0.01 + 1e-9,
            "p99 must keep alpha accuracy"
        );
        let est01 = s.quantile(0.01).unwrap();
        let tru01 = exact.quantile(0.01).unwrap();
        let re01 = (est01 - tru01).abs() / tru01;
        assert!(
            re01 > 0.5,
            "p01 should be badly degraded by first-two collapse, re={re01}"
        );
    }

    #[test]
    fn udd_beats_dd_on_low_quantiles_same_budget() {
        // The paper's §3.2 claim, quantified: same alpha, same budget, wide
        // input -> UDDSketch's worst-quantile error is far below DDSketch's.
        let mut r = default_rng(3);
        let xs: Vec<f64> = (0..50_000)
            .map(|_| 10f64.powf(r.next_f64() * 8.0 - 2.0))
            .collect();
        let mut dd: DdSketch = DdSketch::new(0.01, 32).unwrap();
        let mut udd: UddSketch = UddSketch::new(0.01, 32).unwrap();
        dd.extend(&xs);
        udd.extend(&xs);
        let exact = ExactQuantiles::new(&xs);
        let worst = |est: &dyn Fn(f64) -> f64| {
            [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99]
                .iter()
                .map(|&q| {
                    let t = exact.quantile(q).unwrap();
                    (est(q) - t).abs() / t
                })
                .fold(0.0f64, f64::max)
        };
        let dd_worst = worst(&|q| dd.quantile(q).unwrap());
        let udd_worst = worst(&|q| udd.quantile(q).unwrap());
        assert!(
            udd_worst < dd_worst / 5.0,
            "udd {udd_worst} should be << dd {dd_worst}"
        );
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive() {
        let mut s: DdSketch = DdSketch::new(0.01, 32).unwrap();
        s.insert(0.0);
    }

    #[test]
    fn turnstile_roundtrip() {
        let mut s: DdSketch = DdSketch::new(0.01, 128).unwrap();
        s.insert(5.0);
        s.insert(7.0);
        s.delete(7.0);
        assert_eq!(s.count(), 1.0);
        let est = s.quantile(0.5).unwrap();
        assert!((est - 5.0).abs() <= 0.05 + 0.01 * 5.0);
    }
}
