//! [`Transport`] over a [`SimNet`]: the production gossip loop,
//! membership plane, and wire codec run unmodified — every conversation
//! is encoded to real `UDDX` frames, passed through the fault state,
//! decoded on the far side, and served through the same
//! [`NodeHandle`] entry points the TCP serve loop uses. What TCP pays
//! in sockets, this transport pays in codec round-trips, so a frame the
//! real wire would reject is rejected here too.

use super::net::{LinkOutcome, SimNet};
use crate::gossip::PeerState;
use crate::obs::ExchangeSpan;
use crate::service::membership::MemberTable;
use crate::service::transport::{
    in_process_exchange, ExchangeOutcome, RemoteChannel, Transport, TransportError,
};
use crate::service::{NodeHandle, ServeReject};
use crate::sketch::codec::{
    decode_exchange, decode_exchange_traced, encode_exchange_push_traced,
    encode_exchange_reply_traced, encode_join_request, encode_membership_push,
    encode_membership_reply, ExchangeFrame,
};
use std::net::SocketAddr;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Each delivered frame costs its encoded length plus the 4-byte length
/// prefix the TCP framing pays — byte accounting matches the real wire.
const FRAME_PREFIX: usize = 4;

/// One simulated node's transport endpoint: a synthetic listen address
/// on a shared [`SimNet`].
#[derive(Debug)]
pub struct SimTransport {
    addr: SocketAddr,
    net: Arc<SimNet>,
}

impl SimTransport {
    /// The endpoint `addr` on `net`. The address only becomes servable
    /// once a gossip loop starts on this transport (its
    /// [`Transport::spawn_server`] registers the serve handle).
    pub fn new(net: Arc<SimNet>, addr: SocketAddr) -> Self {
        Self { addr, net }
    }

    /// The shared network this endpoint lives on.
    pub fn net(&self) -> &Arc<SimNet> {
        &self.net
    }
}

impl Transport for SimTransport {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn supports_remote(&self) -> bool {
        true
    }

    fn listen_addr(&self) -> Option<SocketAddr> {
        Some(self.addr)
    }

    fn exchange_local(
        &self,
        a: &mut PeerState,
        b: &mut PeerState,
    ) -> Result<usize, TransportError> {
        in_process_exchange(a, b)
    }

    fn spawn_server(&self, node: NodeHandle) -> crate::Result<Option<JoinHandle<()>>> {
        self.net.register(self.addr, node);
        Ok(None)
    }

    fn open_remote(&self, peer: SocketAddr) -> Result<RemoteChannel, TransportError> {
        // The connect phase: reachability is decided here, like a TCP
        // connect; the channel itself carries no state.
        self.net.connect(self.addr, peer)?;
        Ok(RemoteChannel::new(peer, false, Box::new(())))
    }

    fn exchange_on(
        &self,
        chan: RemoteChannel,
        local: &mut PeerState,
        generation: u64,
    ) -> Result<usize, TransportError> {
        self.exchange_traced(chan, local, generation, 0)
            .map(|o| o.bytes)
    }

    fn exchange_traced(
        &self,
        chan: RemoteChannel,
        local: &mut PeerState,
        generation: u64,
        trace_id: u64,
    ) -> Result<ExchangeOutcome, TransportError> {
        let peer = chan.peer();
        // Re-resolve the handle: a crash or partition may have landed
        // between the two phases of the exchange.
        let handle = self.net.connect(self.addr, peer)?;
        let push = encode_exchange_push_traced(generation, trace_id, local);
        let outcome = self.net.sample_link("exchange", self.addr, peer);
        if outcome == LinkOutcome::PushLost {
            return Err(TransportError::Io(format!(
                "sim push to {peer} lost (deadline)"
            )));
        }
        // The wire round-trip the real transport pays: what the partner
        // serves is the *decoded frame*, not our in-memory state — and
        // the trace id the serve side echoes is the one off the wire.
        let (frame, echoed_id) =
            decode_exchange_traced(&push).map_err(|e| TransportError::Codec(e.to_string()))?;
        let ExchangeFrame::Push {
            generation: pushed_gen,
            state,
        } = frame
        else {
            return Err(TransportError::Protocol(
                "push frame decoded to a non-push kind".into(),
            ));
        };
        let mut reply_frame: Option<Vec<u8>> = None;
        let mut reply_gen = 0u64;
        let served = handle.serve_exchange(state, pushed_gen, |avg, gen| {
            if outcome == LinkOutcome::ReplyLost {
                // The reply never reaches us: the serve side must roll
                // back (§7.2) — this error is what triggers it.
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "sim reply lost (deadline)",
                ));
            }
            reply_gen = gen;
            reply_frame = Some(encode_exchange_reply_traced(gen, echoed_id, avg));
            Ok(())
        });
        match served {
            Ok(()) => {
                let reply = reply_frame.expect("deliver ran on the Ok path");
                let frame = decode_exchange(&reply)
                    .map_err(|e| TransportError::Codec(e.to_string()))?;
                let ExchangeFrame::Reply { state, .. } = frame else {
                    return Err(TransportError::Protocol(
                        "reply frame decoded to a non-reply kind".into(),
                    ));
                };
                let bytes = 2 * FRAME_PREFIX + push.len() + reply.len();
                *local = state;
                self.net
                    .book_delivered("exchange", self.addr, peer, bytes, "");
                // Both halves of the causal record: the serve side's
                // span (echoed id, role `server`) goes to the net's
                // export buffer — sim nodes run no `EventSink` — and
                // the initiator's rides the outcome into its round
                // trace. Wall-clock spans stay zero: virtual time is
                // the only deterministic clock here.
                self.net.export_serve_event(
                    peer,
                    &ExchangeSpan {
                        trace_id: echoed_id,
                        initiator: false,
                        peer: self.addr.to_string(),
                        generation: reply_gen,
                        kind: "full",
                        bytes,
                        outcome: "ok",
                        ..ExchangeSpan::default()
                    },
                );
                let span = ExchangeSpan {
                    trace_id,
                    initiator: true,
                    peer: peer.to_string(),
                    generation,
                    kind: "full",
                    bytes,
                    outcome: "ok",
                    ..ExchangeSpan::default()
                };
                Ok(ExchangeOutcome {
                    bytes,
                    span: Some(span),
                })
            }
            Err(ServeReject::Busy) => {
                self.net
                    .trace_event(&format!("exchange {}->{peer} reject=busy", self.addr));
                Err(TransportError::Busy)
            }
            Err(ServeReject::StaleGeneration(g)) => {
                self.net.trace_event(&format!(
                    "exchange {}->{peer} reject=stale-generation g={g}",
                    self.addr
                ));
                Err(TransportError::StaleGeneration(g))
            }
            Err(ServeReject::Lineage) => {
                Err(TransportError::Lineage("alpha0 lineage mismatch".into()))
            }
            // The §7.2 cancelled exchange: the serve side rolled back,
            // the initiator sees the lost reply as an i/o failure —
            // exactly TCP's shape for the same fault.
            Err(ServeReject::Cancelled(e)) => Err(TransportError::Io(e)),
            Err(ServeReject::NoMembership) => Err(TransportError::NoMembership),
        }
    }

    fn exchange_membership(
        &self,
        peer: SocketAddr,
        generation: u64,
        local: &MemberTable,
    ) -> Result<(MemberTable, u64, usize), TransportError> {
        let handle = self.net.connect(self.addr, peer)?;
        let push = encode_membership_push(generation, local);
        let outcome = self.net.sample_link("membership", self.addr, peer);
        if outcome == LinkOutcome::PushLost {
            return Err(TransportError::Io(format!(
                "sim membership push to {peer} lost"
            )));
        }
        let frame =
            decode_exchange(&push).map_err(|e| TransportError::Codec(e.to_string()))?;
        let ExchangeFrame::MembershipPush {
            generation: pushed_gen,
            table,
        } = frame
        else {
            return Err(TransportError::Protocol(
                "membership push decoded to a different kind".into(),
            ));
        };
        match handle.serve_membership(&table, pushed_gen) {
            Ok((merged, peer_gen)) => {
                // Anti-entropy has no rollback: the partner merged even
                // if our copy of the reply is lost (idempotent merge,
                // next round repairs us).
                if outcome == LinkOutcome::ReplyLost {
                    return Err(TransportError::Io(format!(
                        "sim membership reply from {peer} lost"
                    )));
                }
                let reply = encode_membership_reply(peer_gen, &merged);
                let frame = decode_exchange(&reply)
                    .map_err(|e| TransportError::Codec(e.to_string()))?;
                let ExchangeFrame::MembershipReply { generation, table } = frame else {
                    return Err(TransportError::Protocol(
                        "membership reply decoded to a different kind".into(),
                    ));
                };
                let bytes = 2 * FRAME_PREFIX + push.len() + reply.len();
                self.net
                    .book_delivered("membership", self.addr, peer, bytes, "");
                Ok((table, generation, bytes))
            }
            Err(ServeReject::NoMembership) => Err(TransportError::NoMembership),
            Err(ServeReject::Busy) => Err(TransportError::Busy),
            Err(other) => Err(TransportError::Protocol(other.to_string())),
        }
    }

    fn join_remote(&self, seed: SocketAddr) -> Result<(MemberTable, u64), TransportError> {
        let handle = self.net.connect(self.addr, seed)?;
        let req = encode_join_request(0, self.addr);
        let outcome = self.net.sample_link("join", self.addr, seed);
        if outcome == LinkOutcome::PushLost {
            return Err(TransportError::Io(format!(
                "sim join request to {seed} lost"
            )));
        }
        let frame =
            decode_exchange(&req).map_err(|e| TransportError::Codec(e.to_string()))?;
        let ExchangeFrame::JoinRequest { addr, .. } = frame else {
            return Err(TransportError::Protocol(
                "join request decoded to a different kind".into(),
            ));
        };
        match handle.serve_join(addr) {
            Ok((table, gen)) => {
                // The seed has already inserted us; a lost reply means
                // we retry and rejoin by address (same id, next
                // incarnation) — the handshake's idempotence.
                if outcome == LinkOutcome::ReplyLost {
                    return Err(TransportError::Io(format!(
                        "sim join reply from {seed} lost"
                    )));
                }
                let reply = encode_membership_reply(gen, &table);
                let frame = decode_exchange(&reply)
                    .map_err(|e| TransportError::Codec(e.to_string()))?;
                let ExchangeFrame::MembershipReply { generation, table } = frame else {
                    return Err(TransportError::Protocol(
                        "join reply decoded to a different kind".into(),
                    ));
                };
                let bytes = 2 * FRAME_PREFIX + req.len() + reply.len();
                self.net.book_delivered(
                    "join",
                    self.addr,
                    seed,
                    bytes,
                    &format!("gen={generation}"),
                );
                Ok((table, generation))
            }
            Err(ServeReject::NoMembership) => Err(TransportError::NoMembership),
            Err(other) => Err(TransportError::Protocol(other.to_string())),
        }
    }
}
