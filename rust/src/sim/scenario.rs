//! Scenario descriptions: what a simulated fleet run looks like — size,
//! topology, workload, fault knobs, and the scheduled membership / link
//! events, all keyed off one seed.
//!
//! A scenario comes from one of three places:
//!
//! * a **built-in** by name ([`Scenario::builtin`] — `baseline`,
//!   `churn-storm`, `join-storm`, `lossy`, `partition`), used by CI;
//! * a **scenario file** ([`Scenario::parse`] /
//!   [`Scenario::from_file`]), the line-based format documented in
//!   `docs/SIMULATION.md`;
//! * programmatic construction (the integration tests build them
//!   directly).
//!
//! [`ChurnKind::FailStop`] / the Yao fail-recover models additionally
//! drive crashes and rejoins from [`ChurnModel`] schedules: the model's
//! online mask is precomputed per round, and every `online → offline`
//! transition becomes a crash event (plus a rejoin on the way back for
//! the Yao variants) — §7.2's churn replayed against the production
//! membership plane.
//!
//! [`ChurnModel`]: crate::churn::ChurnModel
//! [`ChurnKind::FailStop`]: crate::churn::ChurnKind::FailStop

use super::net::FaultConfig;
use crate::churn::ChurnKind;
use crate::config::GraphKind;
use crate::data::DatasetKind;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One scheduled action at a given virtual round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventAction {
    /// `count` brand-new members join through live seeds.
    Join(usize),
    /// `count` currently-alive members crash (fail-stop until a
    /// matching rejoin).
    Crash(usize),
    /// `count` previously crashed members recover and rejoin through
    /// live seeds (same address ⇒ same id at the next incarnation).
    Rejoin(usize),
    /// Partition the fleet: the lowest `frac` fraction of alive members
    /// is cut from the rest (both directions) until [`EventAction::Heal`].
    Partition(f64),
    /// Heal the active partition.
    Heal,
    /// Start flapping the partition boundary: the same `frac` cut
    /// toggles blocked/unblocked every `period` rounds until
    /// [`EventAction::Unflap`].
    Flap(f64, u64),
    /// Stop flapping (links settle unblocked).
    Unflap,
}

/// An [`EventAction`] pinned to the virtual round it fires at (applied
/// before that round's exchanges).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledEvent {
    /// The 1-based round the action fires before.
    pub round: u64,
    /// What happens.
    pub action: EventAction,
}

/// A full simulation scenario. Everything that shapes the run lives
/// here except the seed (a CLI/test input, so one scenario replays
/// under many seeds).
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario name (trace header, CI artifact names).
    pub name: String,
    /// Initial fleet size (bootstrap seed + joiners at round 0).
    pub members: usize,
    /// Virtual rounds to run.
    pub rounds: u64,
    /// Sketch α (also the convergence acceptance bound).
    pub alpha: f64,
    /// Sketch bucket budget.
    pub max_buckets: usize,
    /// Values per member's local dataset.
    pub items_per_member: usize,
    /// Exchange fan-out per round.
    pub fan_out: usize,
    /// Overlay topology rebuilt over the live view each churn step.
    pub graph: GraphKind,
    /// Workload each member draws its local dataset from.
    pub dataset: DatasetKind,
    /// Churn model whose schedule drives extra crashes/rejoins.
    pub churn: ChurnKind,
    /// Virtual milliseconds the clock advances per round.
    pub round_ms: u64,
    /// Membership suspicion interval (virtual ms).
    pub suspect_after_ms: u64,
    /// Membership tombstone TTL (virtual ms).
    pub tombstone_ttl_ms: u64,
    /// Link-fault knobs.
    pub faults: FaultConfig,
    /// Run the fleet restart-free (`gossip_restart_free`,
    /// `docs/PROTOCOL.md` §10): joins are admitted into the current
    /// generation and epoch advances carry, so only deaths re-anchor.
    /// `false` replays the PR 5 restart-everything rules — the A/B knob
    /// the churn-cost bench and the join-storm tests flip.
    pub restart_free: bool,
    /// Scheduled membership / link events, in firing order.
    pub events: Vec<ScheduledEvent>,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            name: "baseline".into(),
            members: 32,
            rounds: 30,
            alpha: 0.001,
            max_buckets: 1024,
            items_per_member: 500,
            fan_out: 1,
            graph: GraphKind::Complete,
            dataset: DatasetKind::Uniform,
            churn: ChurnKind::None,
            round_ms: 500,
            suspect_after_ms: 2_000,
            tombstone_ttl_ms: 60_000,
            faults: FaultConfig::default(),
            restart_free: true,
            events: Vec::new(),
        }
    }
}

impl Scenario {
    /// The named built-in scenarios.
    ///
    /// * `baseline` — fault-free convergence reference.
    /// * `churn-storm` — the CI acceptance scenario: joins, a crash
    ///   wave, a partition that heals, lossy links, and rejoins, all
    ///   mid-run.
    /// * `join-storm` — the restart-free churn-cost scenario (ISSUE 9):
    ///   a 1000-member fleet absorbing 120 staggered joins over 50
    ///   rounds on clean links; its CI lane pins each join to O(1)
    ///   extra wire bytes and a never-bumping generation.
    /// * `lossy` — heavy frame loss + delay jitter, no membership
    ///   events (exercises §7.2 cancelled exchanges at volume).
    /// * `partition` — one long asymmetric-healing partition window.
    pub fn builtin(name: &str) -> Result<Self> {
        let mut s = Scenario::default();
        match name {
            "baseline" => {}
            "churn-storm" => {
                s.name = "churn-storm".into();
                s.rounds = 80;
                s.faults.drop_prob = 0.01;
                s.faults.reply_drop_prob = 0.005;
                s.events = vec![
                    ScheduledEvent {
                        round: 5,
                        action: EventAction::Join(join_wave(s.members)),
                    },
                    ScheduledEvent {
                        round: 12,
                        action: EventAction::Crash(crash_wave(s.members)),
                    },
                    ScheduledEvent {
                        round: 20,
                        action: EventAction::Partition(0.25),
                    },
                    ScheduledEvent {
                        round: 28,
                        action: EventAction::Heal,
                    },
                    ScheduledEvent {
                        round: 36,
                        action: EventAction::Rejoin(crash_wave(s.members) / 2),
                    },
                ];
            }
            "join-storm" => {
                s.name = "join-storm".into();
                s.members = 1000;
                s.rounds = 50;
                s.alpha = 0.01;
                s.max_buckets = 256;
                s.items_per_member = 50;
                // Three joins before each of rounds 6..=45: 120 joins
                // staggered over the run, with a settle tail. Links
                // stay clean so the per-round byte accounting isolates
                // the cost of the joins themselves.
                s.events = (6..=45)
                    .map(|round| ScheduledEvent {
                        round,
                        action: EventAction::Join(3),
                    })
                    .collect();
            }
            "lossy" => {
                s.name = "lossy".into();
                s.rounds = 50;
                s.faults = FaultConfig {
                    drop_prob: 0.10,
                    reply_drop_prob: 0.05,
                    delay_base_ms: 20.0,
                    delay_jitter_ms: 60.0,
                    deadline_ms: 120.0,
                };
            }
            "partition" => {
                s.name = "partition".into();
                s.rounds = 60;
                s.events = vec![
                    ScheduledEvent {
                        round: 10,
                        action: EventAction::Partition(0.3),
                    },
                    ScheduledEvent {
                        round: 30,
                        action: EventAction::Heal,
                    },
                ];
            }
            other => bail!(
                "unknown built-in scenario '{other}' \
                 (expected baseline|churn-storm|join-storm|lossy|partition)"
            ),
        }
        Ok(s)
    }

    /// Parse the scenario-file format (see `docs/SIMULATION.md`): one
    /// directive per line, `#` comments, `at <round> <action> [args]`
    /// for events.
    pub fn parse(text: &str) -> Result<Self> {
        let mut s = Scenario::default();
        s.name = "file".into();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("scenario line {}: '{}'", ln + 1, raw.trim());
            let mut it = line.split_whitespace();
            let key = it.next().expect("non-empty line");
            let rest: Vec<&str> = it.collect();
            let one = |rest: &[&str]| -> Result<String> {
                match rest {
                    [v] => Ok((*v).to_string()),
                    _ => bail!("expected exactly one value"),
                }
            };
            match key {
                "name" => s.name = one(&rest).with_context(ctx)?,
                "members" => s.members = one(&rest).with_context(ctx)?.parse().with_context(ctx)?,
                "rounds" => s.rounds = one(&rest).with_context(ctx)?.parse().with_context(ctx)?,
                "alpha" => s.alpha = one(&rest).with_context(ctx)?.parse().with_context(ctx)?,
                "max-buckets" => {
                    s.max_buckets = one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "items" => {
                    s.items_per_member =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "fan-out" => {
                    s.fan_out = one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "graph" => {
                    s.graph = one(&rest)
                        .with_context(ctx)?
                        .parse()
                        .map_err(anyhow::Error::msg)
                        .with_context(ctx)?
                }
                "dataset" => {
                    s.dataset = one(&rest)
                        .with_context(ctx)?
                        .parse()
                        .map_err(anyhow::Error::msg)
                        .with_context(ctx)?
                }
                "churn" => {
                    s.churn = one(&rest)
                        .with_context(ctx)?
                        .parse()
                        .map_err(anyhow::Error::msg)
                        .with_context(ctx)?
                }
                "round-ms" => {
                    s.round_ms = one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "suspect-after-ms" => {
                    s.suspect_after_ms =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "tombstone-ttl-ms" => {
                    s.tombstone_ttl_ms =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "drop-prob" => {
                    s.faults.drop_prob =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "reply-drop-prob" => {
                    s.faults.reply_drop_prob =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "delay-base-ms" => {
                    s.faults.delay_base_ms =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "delay-jitter-ms" => {
                    s.faults.delay_jitter_ms =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "deadline-ms" => {
                    s.faults.deadline_ms =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "restart-free" => {
                    s.restart_free =
                        one(&rest).with_context(ctx)?.parse().with_context(ctx)?
                }
                "at" => {
                    let ev = Self::parse_event(&rest).with_context(ctx)?;
                    s.events.push(ev);
                }
                other => bail!("{}: unknown directive '{other}'", ctx()),
            }
        }
        s.events.sort_by_key(|e| e.round);
        Ok(s)
    }

    /// [`Scenario::parse`] over a file's contents.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario file {}", path.display()))?;
        let mut s = Self::parse(&text)?;
        if s.name == "file" {
            if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                s.name = stem.to_string();
            }
        }
        Ok(s)
    }

    fn parse_event(rest: &[&str]) -> Result<ScheduledEvent> {
        let [round, action, args @ ..] = rest else {
            bail!("expected 'at <round> <action> [args]'");
        };
        let round: u64 = round.parse().context("event round")?;
        let action = match (*action, args) {
            ("join", [n]) => EventAction::Join(n.parse().context("join count")?),
            ("crash", [n]) => EventAction::Crash(n.parse().context("crash count")?),
            ("rejoin", [n]) => EventAction::Rejoin(n.parse().context("rejoin count")?),
            ("partition", [f]) => {
                EventAction::Partition(f.parse().context("partition fraction")?)
            }
            ("heal", []) => EventAction::Heal,
            ("flap", [f, p]) => EventAction::Flap(
                f.parse().context("flap fraction")?,
                p.parse().context("flap period")?,
            ),
            ("unflap", []) => EventAction::Unflap,
            (other, _) => bail!(
                "unknown event '{other}' (expected \
                 join|crash|rejoin|partition|heal|flap|unflap, with its args)"
            ),
        };
        Ok(ScheduledEvent { round, action })
    }

    /// Basic sanity checks before a run (sizes, probabilities, event
    /// rounds inside the run).
    pub fn validate(&self) -> Result<()> {
        if self.members < 2 {
            bail!("scenario needs at least 2 members, got {}", self.members);
        }
        if self.rounds == 0 {
            bail!("scenario needs at least 1 round");
        }
        if !(self.alpha > 0.0 && self.alpha < 1.0) {
            bail!("alpha must be in (0, 1), got {}", self.alpha);
        }
        if self.fan_out == 0 {
            bail!("fan-out must be >= 1");
        }
        if self.round_ms == 0 {
            bail!("round-ms must be >= 1");
        }
        for p in [self.faults.drop_prob, self.faults.reply_drop_prob] {
            if !(0.0..=1.0).contains(&p) {
                bail!("fault probabilities must be in [0, 1], got {p}");
            }
        }
        for e in &self.events {
            if e.round == 0 || e.round > self.rounds {
                bail!(
                    "event at round {} falls outside the run (1..={})",
                    e.round,
                    self.rounds
                );
            }
            if let EventAction::Partition(f) | EventAction::Flap(f, _) = e.action {
                if !(0.0..1.0).contains(&f) || f <= 0.0 {
                    bail!("partition fraction must be in (0, 1), got {f}");
                }
            }
            if let EventAction::Flap(_, p) = e.action {
                if p == 0 {
                    bail!("flap period must be >= 1 round");
                }
            }
        }
        Ok(())
    }
}

/// Join-wave size of the churn-storm scenario: 5% of the fleet, at
/// least 3.
fn join_wave(members: usize) -> usize {
    (members / 20).max(3)
}

/// Crash-wave size of the churn-storm scenario: 10% of the fleet, at
/// least 4.
fn crash_wave(members: usize) -> usize {
    (members / 10).max(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_validate() {
        for name in ["baseline", "churn-storm", "join-storm", "lossy", "partition"] {
            let s = Scenario::builtin(name).unwrap();
            s.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(Scenario::builtin("nope").is_err());

        // join-storm is the ISSUE 9 churn-cost scenario: at least 100
        // staggered joins, restart-free, clean links.
        let js = Scenario::builtin("join-storm").unwrap();
        assert!(js.restart_free);
        assert_eq!(js.faults.drop_prob, 0.0);
        let joins: usize = js
            .events
            .iter()
            .map(|e| match e.action {
                EventAction::Join(n) => n,
                _ => panic!("join-storm schedules only joins"),
            })
            .sum();
        assert!(joins >= 100, "join-storm must stagger >= 100 joins ({joins})");
    }

    #[test]
    fn parse_round_trips_the_documented_format() {
        let text = "
# the documented example
name storm-test
members 100
rounds 40
alpha 0.01
items 200
fan-out 2
graph ba
dataset exponential
churn none
round-ms 250
suspect-after-ms 1000
tombstone-ttl-ms 9000
drop-prob 0.02
reply-drop-prob 0.01
delay-base-ms 5
delay-jitter-ms 15
deadline-ms 100
restart-free false
at 5 join 10
at 12 crash 8        # a comment after an event
at 15 partition 0.3
at 20 heal
at 25 flap 0.2 2
at 30 unflap
at 33 rejoin 4
";
        let s = Scenario::parse(text).unwrap();
        s.validate().unwrap();
        assert_eq!(s.name, "storm-test");
        assert_eq!(s.members, 100);
        assert_eq!(s.rounds, 40);
        assert_eq!(s.graph, GraphKind::BarabasiAlbert);
        assert_eq!(s.dataset, DatasetKind::Exponential);
        assert_eq!(s.fan_out, 2);
        assert_eq!(s.faults.drop_prob, 0.02);
        assert!(!s.restart_free, "restart-free false must parse");
        assert_eq!(s.events.len(), 7);
        assert_eq!(
            s.events[0],
            ScheduledEvent {
                round: 5,
                action: EventAction::Join(10)
            }
        );
        assert_eq!(s.events[4].action, EventAction::Flap(0.2, 2));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Scenario::parse("members").is_err());
        assert!(Scenario::parse("bogus 3").is_err());
        assert!(Scenario::parse("at 5 explode 1").is_err());
        assert!(Scenario::parse("graph dodecahedron").is_err());
        let out_of_run = Scenario::parse("rounds 10\nat 99 heal").unwrap();
        assert!(out_of_run.validate().is_err());
    }
}
