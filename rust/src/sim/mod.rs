//! Deterministic discrete-event simulation of whole gossip fleets in
//! one process (`docs/SIMULATION.md`).
//!
//! The point of this module is that **nothing under test is
//! simulated**: the production [`GossipLoop`], membership plane, and
//! wire codec run unmodified. Only the two ambient dependencies are
//! swapped for deterministic doubles:
//!
//! * **time** — every node's [`Membership`] reads a shared
//!   [`VirtualClock`] that advances only when the fleet says so;
//! * **the network** — [`SimTransport`] implements the [`Transport`]
//!   trait over a [`SimNet`], which owns the fault state: per-link
//!   drop probabilities, delay distributions checked against a
//!   deadline, crashes, and (asymmetric, directed) partitions.
//!
//! A [`Scenario`] describes a run — fleet size, overlay topology,
//! workload, fault knobs, and scheduled events (joins, crash waves,
//! partitions that heal, flapping links, churn-model schedules). A
//! [`SimFleet`] executes it round by round, steps every alive node in
//! sorted id order from a single thread, and checks the fleet's union
//! estimate against the exact oracle each virtual round. Because the
//! stepping order, rng draws, clock, and every iterated collection are
//! deterministic, the same `(scenario, seed)` pair produces a
//! **byte-identical event trace** — the property the `sim-fleet` CI
//! lane asserts by diffing two runs.
//!
//! That property is also enforced statically: the `collections` and
//! `ambient-time` rules of `dudd-analyze` (see `docs/ANALYSIS.md`)
//! forbid hash-ordered collections and wall-clock reads in this
//! subtree.
//!
//! [`GossipLoop`]: crate::service::GossipLoop
//! [`Membership`]: crate::service::Membership
//! [`VirtualClock`]: crate::service::VirtualClock
//! [`Transport`]: crate::service::Transport

#![forbid(unsafe_code)]

mod fleet;
mod net;
mod scenario;
mod transport;

pub use fleet::{RoundLog, SimFleet, SimReport};
pub use net::{sim_addr, FaultConfig, NetStats, SimNet};
pub use scenario::{EventAction, Scenario, ScheduledEvent};
pub use transport::SimTransport;
