//! The simulated network: a registry of in-process nodes addressed by
//! synthetic socket addresses, plus the fault state every conversation
//! is checked against.
//!
//! One [`SimNet`] is shared (`Arc`) by every [`SimTransport`] of a
//! fleet. All state sits behind one mutex and the fleet steps nodes
//! one at a time from a single thread, so the fault rng draws in a
//! deterministic order — the root of the same-seed ⇒ byte-identical
//! trace guarantee. Collections are `BTreeMap`/`BTreeSet`, never hash
//! maps, so no iteration ever depends on hasher state.
//!
//! [`SimTransport`]: super::SimTransport

use crate::obs::{encode_exchange_event, ExchangeSpan};
use crate::rng::{default_rng, Rng, Xoshiro256pp};
use crate::service::clock::VirtualClock;
use crate::service::transport::TransportError;
use crate::service::NodeHandle;
use std::collections::{BTreeMap, BTreeSet};
use std::net::SocketAddr;
use std::sync::{Arc, Mutex, MutexGuard};

/// Link-fault knobs of a simulated network — the fault vocabulary of
/// `docs/SIMULATION.md`. All probabilities are per *conversation* (one
/// framed push–pull), not per byte.
#[derive(Debug, Clone, Copy)]
pub struct FaultConfig {
    /// Probability the push frame is lost in flight: the partner never
    /// serves, the initiator times out (`TransportError::Io`).
    pub drop_prob: f64,
    /// Probability the reply frame is lost *after* the partner served:
    /// the serve side rolls back (§7.2 cancelled exchange) and the
    /// initiator times out — the Two-Generals-shaped failure mode the
    /// protocol's rollback contract exists for.
    pub reply_drop_prob: f64,
    /// Base one-way link delay, virtual milliseconds.
    pub delay_base_ms: f64,
    /// Uniform jitter added on top of the base delay, per leg.
    pub delay_jitter_ms: f64,
    /// Per-conversation deadline, virtual milliseconds: a sampled
    /// round-trip (push leg + reply leg) above it times the exchange
    /// out exactly like `gossip_exchange_deadline_ms` does over TCP.
    /// `0` disables the deadline.
    pub deadline_ms: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            drop_prob: 0.0,
            reply_drop_prob: 0.0,
            delay_base_ms: 0.0,
            delay_jitter_ms: 0.0,
            deadline_ms: 200.0,
        }
    }
}

/// How the fault state disposed of one conversation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum LinkOutcome {
    /// Both legs survive: serve, then deliver the reply.
    Delivered,
    /// The push leg was lost (drop or push-leg delay past the
    /// deadline): the partner never hears it.
    PushLost,
    /// The reply leg was lost (drop or round-trip past the deadline):
    /// the partner served but must roll back.
    ReplyLost,
}

/// Cumulative conversation counters (all frame kinds).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Conversations fully delivered.
    pub delivered: u64,
    /// Conversations whose push leg was lost.
    pub push_lost: u64,
    /// Conversations whose reply leg was lost (serve side rolled back).
    pub reply_lost: u64,
    /// Connect attempts refused (crashed / partitioned / unregistered).
    pub refused: u64,
    /// Wire bytes moved by delivered frames (length prefix included,
    /// matching the TCP transport's accounting).
    pub bytes: u64,
}

struct NetInner {
    nodes: BTreeMap<SocketAddr, NodeHandle>,
    crashed: BTreeSet<SocketAddr>,
    /// Directed blocked links `(src, dst)` — an asymmetric partition is
    /// one direction only.
    blocked: BTreeSet<(SocketAddr, SocketAddr)>,
    faults: FaultConfig,
    rng: Xoshiro256pp,
    round: u64,
    trace: Vec<String>,
    stats: NetStats,
    /// When set, served exchanges also land in `serve_events` as
    /// production-schema JSONL (the sim's server-side half of the
    /// cross-node trace join — there is no per-node `EventSink` here).
    export_events: bool,
    serve_events: Vec<String>,
}

/// The shared simulated network of one fleet: node registry, fault
/// state, virtual clock, and the deterministic event trace.
pub struct SimNet {
    clock: Arc<VirtualClock>,
    inner: Mutex<NetInner>,
}

impl std::fmt::Debug for SimNet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.lock();
        write!(
            f,
            "SimNet(nodes={}, crashed={}, blocked_links={}, round={})",
            inner.nodes.len(),
            inner.crashed.len(),
            inner.blocked.len(),
            inner.round
        )
    }
}

impl SimNet {
    /// A fresh network: fault rng derived from `seed`, virtual clock at
    /// zero, no nodes, no faults active beyond `faults`' probabilities.
    pub fn new(seed: u64, faults: FaultConfig) -> Arc<Self> {
        Arc::new(Self {
            clock: Arc::new(VirtualClock::new()),
            inner: Mutex::new(NetInner {
                nodes: BTreeMap::new(),
                crashed: BTreeSet::new(),
                blocked: BTreeSet::new(),
                faults,
                rng: default_rng(seed).derive(0xFA17),
                round: 0,
                trace: Vec::new(),
                stats: NetStats::default(),
                export_events: false,
                serve_events: Vec::new(),
            }),
        })
    }

    /// The fleet-wide virtual clock (share it with every node's
    /// [`Membership`](crate::service::Membership)).
    pub fn clock(&self) -> Arc<VirtualClock> {
        self.clock.clone()
    }

    fn lock(&self) -> MutexGuard<'_, NetInner> {
        self.inner.lock().expect("sim net poisoned")
    }

    /// Register (or replace, on rejoin) the serve handle behind `addr`.
    pub(crate) fn register(&self, addr: SocketAddr, node: NodeHandle) {
        self.lock().nodes.insert(addr, node);
    }

    /// Mark `addr` crashed: unreachable in both directions until
    /// [`SimNet::recover`]. The node object itself is untouched — the
    /// fleet just stops stepping it.
    pub fn crash(&self, addr: SocketAddr) {
        self.lock().crashed.insert(addr);
    }

    /// Clear `addr`'s crashed flag (fail-recover rejoin).
    pub fn recover(&self, addr: SocketAddr) {
        self.lock().crashed.remove(&addr);
    }

    /// Block the directed link `src → dst` (asymmetric partition half).
    pub fn block(&self, src: SocketAddr, dst: SocketAddr) {
        self.lock().blocked.insert((src, dst));
    }

    /// Unblock the directed link `src → dst`.
    pub fn unblock(&self, src: SocketAddr, dst: SocketAddr) {
        self.lock().blocked.remove(&(src, dst));
    }

    /// Current virtual round (set by the driving fleet; trace prefix).
    pub fn set_round(&self, round: u64) {
        self.lock().round = round;
    }

    /// Append a fleet-level line to the event trace, prefixed like the
    /// network's own entries (`r=<round> t=<virtual ms>`).
    pub fn trace_event(&self, line: &str) {
        let t = self.clock.elapsed().as_millis();
        let mut inner = self.lock();
        let r = inner.round;
        inner.trace.push(format!("r={r} t={t}ms {line}"));
    }

    /// Drain the accumulated event trace.
    pub fn take_trace(&self) -> Vec<String> {
        std::mem::take(&mut self.lock().trace)
    }

    /// Cumulative conversation counters.
    pub fn stats(&self) -> NetStats {
        self.lock().stats
    }

    /// Turn on server-side exchange-span export: every exchange a node
    /// serves is encoded as one production-schema `exchange` JSONL line
    /// (role `server`, the push's trace id echoed) into an internal
    /// buffer, drained with [`SimNet::take_serve_events`]. Off by
    /// default — [`SimFleet`](super::SimFleet) enables it for
    /// event-exporting runs only.
    pub fn enable_event_export(&self) {
        self.lock().export_events = true;
    }

    /// Record a server-side exchange span for the node at `addr`, when
    /// export is enabled. Timestamped off the virtual clock; the round
    /// is the fleet's current virtual round.
    pub(crate) fn export_serve_event(&self, addr: SocketAddr, span: &ExchangeSpan) {
        let t = self.clock.elapsed().as_millis() as u64;
        let mut inner = self.lock();
        if !inner.export_events {
            return;
        }
        let round = inner.round;
        inner
            .serve_events
            .push(encode_exchange_event(&addr.to_string(), t, round, span));
    }

    /// Drain the server-side event lines accumulated since the last
    /// call (empty unless [`SimNet::enable_event_export`] ran).
    pub fn take_serve_events(&self) -> Vec<String> {
        std::mem::take(&mut self.lock().serve_events)
    }

    fn push_trace(inner: &mut NetInner, t_ms: u128, line: String) {
        let r = inner.round;
        inner.trace.push(format!("r={r} t={t_ms}ms {line}"));
    }

    /// The connect phase: can `src` reach `dst` right now? Checks the
    /// registry, both crash flags, and the directed partition state
    /// (a TCP connect needs both directions, so either blocked half
    /// refuses it). Returns the serve handle on success.
    pub(crate) fn connect(
        &self,
        src: SocketAddr,
        dst: SocketAddr,
    ) -> Result<NodeHandle, TransportError> {
        let t = self.clock.elapsed().as_millis();
        let mut inner = self.lock();
        let why = if inner.crashed.contains(&src) {
            Some("self-crashed")
        } else if inner.crashed.contains(&dst) {
            Some("peer-crashed")
        } else if inner.blocked.contains(&(src, dst)) || inner.blocked.contains(&(dst, src)) {
            Some("partitioned")
        } else if !inner.nodes.contains_key(&dst) {
            Some("unregistered")
        } else {
            None
        };
        if let Some(why) = why {
            inner.stats.refused += 1;
            Self::push_trace(&mut inner, t, format!("connect {src}->{dst} refused={why}"));
            return Err(TransportError::Io(format!(
                "sim connect {src} -> {dst} refused ({why})"
            )));
        }
        Ok(inner.nodes.get(&dst).expect("checked above").clone())
    }

    /// Sample one conversation's fate: drop draws and the two delay
    /// legs against the deadline. Exactly four rng draws per call, so
    /// the stream stays aligned whatever the outcome.
    pub(crate) fn sample_link(&self, kind: &str, src: SocketAddr, dst: SocketAddr) -> LinkOutcome {
        let t = self.clock.elapsed().as_millis();
        let mut inner = self.lock();
        let f = inner.faults;
        let push_dropped = inner.rng.chance(f.drop_prob);
        let reply_dropped = inner.rng.chance(f.reply_drop_prob);
        let push_delay = f.delay_base_ms + inner.rng.next_f64() * f.delay_jitter_ms;
        let reply_delay = f.delay_base_ms + inner.rng.next_f64() * f.delay_jitter_ms;
        let deadline = if f.deadline_ms > 0.0 {
            f.deadline_ms
        } else {
            f64::INFINITY
        };
        let outcome = if push_dropped || push_delay > deadline {
            LinkOutcome::PushLost
        } else if reply_dropped || push_delay + reply_delay > deadline {
            LinkOutcome::ReplyLost
        } else {
            LinkOutcome::Delivered
        };
        match outcome {
            LinkOutcome::PushLost => {
                inner.stats.push_lost += 1;
                Self::push_trace(&mut inner, t, format!("{kind} {src}->{dst} lost=push"));
            }
            LinkOutcome::ReplyLost => {
                inner.stats.reply_lost += 1;
                Self::push_trace(&mut inner, t, format!("{kind} {src}->{dst} lost=reply"));
            }
            LinkOutcome::Delivered => {}
        }
        outcome
    }

    /// Book a fully delivered conversation: bytes on the wire and one
    /// trace line.
    pub(crate) fn book_delivered(
        &self,
        kind: &str,
        src: SocketAddr,
        dst: SocketAddr,
        bytes: usize,
        detail: &str,
    ) {
        let t = self.clock.elapsed().as_millis();
        let mut inner = self.lock();
        inner.stats.delivered += 1;
        inner.stats.bytes += bytes as u64;
        let sep = if detail.is_empty() { "" } else { " " };
        Self::push_trace(
            &mut inner,
            t,
            format!("{kind} {src}->{dst} ok bytes={bytes}{sep}{detail}"),
        );
    }
}

/// Synthetic, deterministic listen address for simulated member `id`:
/// `10.x.y.z:7000` with the id packed into the lower three octets
/// (unique up to 2²⁴ members, far past any simulation size).
pub fn sim_addr(id: u64) -> SocketAddr {
    SocketAddr::from((
        [
            10,
            ((id >> 16) & 0xFF) as u8,
            ((id >> 8) & 0xFF) as u8,
            (id & 0xFF) as u8,
        ],
        7000,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_addrs_are_unique_and_deterministic() {
        let mut seen = std::collections::BTreeSet::new();
        for id in 0..2000 {
            assert_eq!(sim_addr(id), sim_addr(id));
            assert!(seen.insert(sim_addr(id)), "collision at id {id}");
        }
    }

    #[test]
    fn link_sampling_is_deterministic_per_seed() {
        let run = || {
            let net = SimNet::new(
                7,
                FaultConfig {
                    drop_prob: 0.3,
                    reply_drop_prob: 0.3,
                    delay_base_ms: 10.0,
                    delay_jitter_ms: 50.0,
                    deadline_ms: 60.0,
                },
            );
            (0..200)
                .map(|i| net.sample_link("x", sim_addr(i), sim_addr(i + 1)) as u8)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn partition_refuses_both_directions_until_unblocked() {
        let net = SimNet::new(1, FaultConfig::default());
        let (a, b) = (sim_addr(1), sim_addr(2));
        net.block(a, b);
        assert!(net.connect(a, b).is_err());
        assert!(net.connect(b, a).is_err(), "TCP needs both directions");
        net.unblock(a, b);
        // Still unregistered, but no longer partitioned.
        let err = format!("{}", net.connect(a, b).unwrap_err());
        assert!(err.contains("unregistered"), "{err}");
    }
}
