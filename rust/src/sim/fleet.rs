//! The fleet runner: builds a whole simulated fleet (bootstrap seed +
//! joiners) on one [`SimNet`], then drives it round by round — advance
//! the virtual clock, apply the scenario's scheduled events and churn
//! schedule, step every alive node's production [`GossipLoop`] in
//! sorted id order, and check the fleet's union estimate against the
//! exact oracle.
//!
//! Everything the run does is a deterministic function of
//! `(scenario, seed)`: the nodes step single-threaded in a fixed
//! order, the fault rng draws in that same order, the virtual clock
//! only moves when the fleet advances it, and every collection
//! iterated is ordered. Two runs with the same inputs therefore
//! produce byte-identical event traces and JSON logs —
//! [`SimReport::trace_text`] is diffable across runs, machines, and
//! CI shards.

use super::net::{sim_addr, NetStats, SimNet};
use super::scenario::{EventAction, Scenario};
use super::transport::SimTransport;
use crate::churn::{ChurnKind, ChurnModel};
use crate::config::GossipLoopConfig;
use crate::data::peer_dataset;
use crate::obs::{encode_exchange_event, encode_membership_event, encode_round_event};
use crate::rng::default_rng;
use crate::service::{
    GossipLoop, GossipMember, GossipRoundReport, Membership, MembershipConfig, Transport,
};
use crate::sketch::{theorem2_bound, ExactQuantiles};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

/// Quantiles the oracle check probes each round.
const ERR_QUANTILES: [f64; 7] = [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99];

/// Join handshake retry budget (lossy links can eat join frames).
const JOIN_ATTEMPTS: usize = 8;

/// Slope of the O(log n) reference curve: push–pull gossip diffuses in
/// `O(log n)` rounds; the reported reference is `⌈C·log₂(n)⌉` with a
/// generous constant so the curve is a sanity anchor, not a hard gate.
const REFERENCE_C: f64 = 3.0;

/// One node of the simulated fleet: its identity, its local dataset
/// (the oracle's share), and the production gossip loop driving it.
struct SimNode {
    id: u64,
    addr: SocketAddr,
    /// Stable dataset ordinal — survives crash/rejoin cycles, keys
    /// [`peer_dataset`] and the churn schedule.
    ordinal: u64,
    dataset: Vec<f64>,
    gossip: GossipLoop,
}

/// A crashed node awaiting (maybe) a rejoin. Only identity is kept;
/// the dataset is recomputed from the ordinal on rejoin.
struct DownedNode {
    addr: SocketAddr,
    ordinal: u64,
}

/// An active flapping-links schedule (the [`EventAction::Flap`] state).
struct FlapState {
    pairs: Vec<(SocketAddr, SocketAddr)>,
    period: u64,
    started: u64,
    blocked: bool,
}

/// Cached exact oracle over the union of the *alive* members' datasets,
/// keyed by the alive id set.
struct OracleCache {
    key: Vec<u64>,
    exact: ExactQuantiles,
    /// Acceptance bound for this union: twice the Theorem 2 bound of
    /// the union's range under the scenario's bucket budget (the
    /// doubling covers rank discretization when quantile ranks fall on
    /// bucket boundaries of *averaged*, fractional counts), floored at
    /// the configured α.
    tol: f64,
}

/// Per-round telemetry, one entry per virtual round
/// ([`SimReport::rounds`]).
#[derive(Debug, Clone)]
pub struct RoundLog {
    /// 1-based virtual round.
    pub round: u64,
    /// Nodes alive (stepped) this round.
    pub alive: usize,
    /// Nodes currently crashed.
    pub downed: usize,
    /// Completed push–pull exchanges, summed over the fleet.
    pub exchanges: usize,
    /// Cancelled exchanges (§7.2), summed over the fleet.
    pub failed: usize,
    /// Exchange-plane wire bytes this round.
    pub bytes: usize,
    /// Membership anti-entropy wire bytes this round.
    pub membership_bytes: usize,
    /// Highest restart generation observed across the sampled nodes.
    pub generation: u64,
    /// Worst relative value error of the sampled nodes' estimates vs
    /// the exact union oracle, across [`ERR_QUANTILES`].
    pub max_rel_err: f64,
    /// Whether `max_rel_err` is within the oracle's acceptance bound.
    pub within_tol: bool,
    /// Membership / link events applied before this round.
    pub events: Vec<String>,
}

/// The outcome of one fleet run: the per-round log, the convergence
/// verdict, the network counters, and the full deterministic event
/// trace.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Scenario name.
    pub scenario: String,
    /// The run's seed.
    pub seed: u64,
    /// Initial fleet size.
    pub members_initial: usize,
    /// Peak fleet size over the run (joins included).
    pub members_peak: usize,
    /// Per-round telemetry.
    pub rounds: Vec<RoundLog>,
    /// The final round's acceptance bound (see [`SimReport::converged_round`]).
    pub tol: f64,
    /// First round of the trailing streak where every sampled estimate
    /// stayed within the bound through the end of the run — the
    /// rounds-to-convergence figure. `None` when the final round is
    /// still outside the bound.
    pub converged_round: Option<u64>,
    /// The O(log n) reference: `⌈3·log₂(peak members)⌉` rounds.
    pub reference_rounds: u64,
    /// The final round's worst sampled relative error.
    pub final_max_rel_err: f64,
    /// Cumulative network counters.
    pub net: NetStats,
    /// The deterministic event trace (same seed ⇒ byte-identical).
    pub trace: Vec<String>,
    /// Structured JSONL event lines in the production event-log schema
    /// (`docs/OBSERVABILITY.md`), timestamped off the virtual clock.
    /// Empty unless the run was built with
    /// [`SimFleet::with_event_export`]. Same seed ⇒ byte-identical.
    pub events_jsonl: Vec<String>,
}

impl SimReport {
    /// The trace as one newline-terminated text block — the artifact
    /// two same-seed runs are diffed over.
    pub fn trace_text(&self) -> String {
        let mut out = String::new();
        for line in &self.trace {
            out.push_str(line);
            out.push('\n');
        }
        out
    }

    /// The structured event log as one newline-terminated JSONL block —
    /// the same schema a production node's `obs_event_log` file uses,
    /// so `dudd-observe`'s trace join and the property tests consume
    /// sim logs and production logs through one parser.
    pub fn events_text(&self) -> String {
        let mut out = String::new();
        for line in &self.events_jsonl {
            out.push_str(line);
            out.push('\n');
        }
        out
    }

    /// The whole report as a JSON document (hand-rolled — the crate
    /// carries no serialization dependency). Layout:
    /// `{"scenario":…,"seed":…,"rounds":[…],"summary":{…}}` with one
    /// object per round in `rounds`.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.rounds.len() * 160);
        out.push_str("{\"scenario\":");
        push_json_str(&mut out, &self.scenario);
        out.push_str(&format!(
            ",\"seed\":{},\"members_initial\":{},\"members_peak\":{},\"rounds\":[",
            self.seed, self.members_initial, self.members_peak
        ));
        for (i, r) in self.rounds.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n{{\"round\":{},\"alive\":{},\"downed\":{},\"exchanges\":{},\
                 \"failed\":{},\"bytes\":{},\"membership_bytes\":{},\
                 \"generation\":{},\"max_rel_err\":{},\"within_tol\":{},\
                 \"events\":[",
                r.round,
                r.alive,
                r.downed,
                r.exchanges,
                r.failed,
                r.bytes,
                r.membership_bytes,
                r.generation,
                json_f64(r.max_rel_err),
                r.within_tol,
            ));
            for (j, e) in r.events.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                push_json_str(&mut out, e);
            }
            out.push_str("]}");
        }
        out.push_str(&format!(
            "\n],\"summary\":{{\"converged_round\":{},\"reference_rounds\":{},\
             \"tol\":{},\"final_max_rel_err\":{},\"delivered\":{},\
             \"push_lost\":{},\"reply_lost\":{},\"refused\":{},\
             \"wire_bytes\":{},\"trace_lines\":{}}}}}\n",
            match self.converged_round {
                Some(r) => r.to_string(),
                None => "null".into(),
            },
            self.reference_rounds,
            json_f64(self.tol),
            json_f64(self.final_max_rel_err),
            self.net.delivered,
            self.net.push_lost,
            self.net.reply_lost,
            self.net.refused,
            self.net.bytes,
            self.trace.len(),
        ));
        out
    }
}

/// A finite f64 as a JSON number, non-finite as `null` (JSON has no
/// infinities).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

/// Append `s` as a JSON string literal (escaping the characters our
/// event vocabulary can produce).
fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// A whole simulated fleet plus its scenario driver. Build with
/// [`SimFleet::new`] (which boots the seed node and joins the initial
/// members through the production handshake), then [`SimFleet::run`].
pub struct SimFleet {
    scenario: Scenario,
    seed: u64,
    cfg: GossipLoopConfig,
    net: Arc<SimNet>,
    /// Alive nodes by member id — stepping iterates this in order.
    nodes: BTreeMap<u64, SimNode>,
    /// Crashed nodes by member id.
    downed: BTreeMap<u64, DownedNode>,
    /// Next fresh dataset ordinal (addresses derive from ordinals).
    next_ordinal: u64,
    /// Precomputed churn-model online mask per round (empty when the
    /// scenario's churn kind is `None`).
    churn_schedule: Vec<Vec<bool>>,
    churn_prev: Vec<bool>,
    /// Blocked pairs of the active [`EventAction::Partition`], if any.
    partition: Vec<(SocketAddr, SocketAddr)>,
    flap: Option<FlapState>,
    oracle: Option<OracleCache>,
    members_peak: usize,
    /// When set, every stepped round also lands in
    /// [`SimFleet::event_lines`] as production-schema JSONL.
    export_events: bool,
    event_lines: Vec<String>,
}

impl SimFleet {
    /// Boot the fleet: node 0 bootstraps the membership plane, the
    /// remaining `scenario.members - 1` nodes join through the
    /// production `dudd-join` handshake (over the simulated links, so
    /// a lossy scenario can already cost join retries here).
    pub fn new(scenario: Scenario, seed: u64) -> Result<Self> {
        scenario.validate()?;
        let net = SimNet::new(seed, scenario.faults);
        let cfg = gossip_cfg(&scenario, seed);
        let members = scenario.members;
        let churn_schedule = match scenario.churn {
            ChurnKind::None => Vec::new(),
            kind => ChurnModel::new(kind, members, &default_rng(seed))
                .schedule(scenario.rounds as usize, members),
        };
        let mut fleet = Self {
            scenario,
            seed,
            cfg,
            net,
            nodes: BTreeMap::new(),
            downed: BTreeMap::new(),
            next_ordinal: 0,
            churn_schedule,
            churn_prev: vec![true; members],
            partition: Vec::new(),
            flap: None,
            oracle: None,
            members_peak: 0,
            export_events: false,
            event_lines: Vec::new(),
        };
        fleet.boot_seed_node().context("booting the seed node")?;
        for ordinal in 1..members as u64 {
            let node = fleet
                .start_joiner(ordinal)
                .with_context(|| format!("joining initial member ordinal {ordinal}"))?;
            fleet.insert_node(node);
        }
        fleet.next_ordinal = members as u64;
        fleet.members_peak = members;
        fleet
            .net
            .trace_event(&format!("fleet booted members={members}"));
        Ok(fleet)
    }

    /// Number of alive nodes.
    pub fn alive(&self) -> usize {
        self.nodes.len()
    }

    /// Turn on structured event export: every stepped round emits
    /// `round`/`exchange`/`membership` JSONL lines in the production
    /// event-log schema into [`SimReport::events_jsonl`], timestamped
    /// off the virtual clock with the wall-clock spans zeroed — the
    /// export is part of the deterministic surface (same seed ⇒
    /// byte-identical lines).
    pub fn with_event_export(mut self) -> Self {
        self.export_events = true;
        self.net.enable_event_export();
        self
    }

    /// The shared simulated network (tests inject extra faults here).
    pub fn net(&self) -> &Arc<SimNet> {
        &self.net
    }

    fn boot_seed_node(&mut self) -> Result<()> {
        let addr = sim_addr(0);
        let dataset = self.dataset_for(0);
        let membership = Membership::bootstrap_with_clock(
            addr,
            MembershipConfig::from_gossip(&self.cfg),
            self.net.clock(),
        );
        let member =
            GossipMember::from_dataset(&dataset, self.scenario.alpha, self.scenario.max_buckets)?;
        let transport: Arc<dyn Transport> =
            Arc::new(SimTransport::new(self.net.clone(), addr));
        let gossip = GossipLoop::start_membership_member(
            self.cfg.clone(),
            member,
            transport,
            Arc::new(membership),
            1,
        )?;
        self.insert_node(SimNode {
            id: 0,
            addr,
            ordinal: 0,
            dataset,
            gossip,
        });
        Ok(())
    }

    fn dataset_for(&self, ordinal: u64) -> Vec<f64> {
        peer_dataset(
            self.scenario.dataset,
            ordinal as usize,
            self.scenario.items_per_member,
            &default_rng(self.seed),
        )
    }

    fn insert_node(&mut self, node: SimNode) {
        self.nodes.insert(node.id, node);
        self.oracle = None;
        let total = self.nodes.len() + self.downed.len();
        self.members_peak = self.members_peak.max(total);
    }

    /// Build and start a node at `ordinal`'s address by joining through
    /// the lowest-id alive seeds (retried — lossy links can eat the
    /// handshake frames).
    fn start_joiner(&self, ordinal: u64) -> Result<SimNode> {
        let addr = sim_addr(ordinal);
        let dataset = self.dataset_for(ordinal);
        let transport = Arc::new(SimTransport::new(self.net.clone(), addr));
        let seeds: Vec<SocketAddr> =
            self.nodes.values().take(3).map(|n| n.addr).collect();
        anyhow::ensure!(!seeds.is_empty(), "no alive seed to join through");
        let mut joined = None;
        'attempts: for _ in 0..JOIN_ATTEMPTS {
            for &seed_addr in &seeds {
                if let Ok(ok) = transport.join_remote(seed_addr) {
                    joined = Some(ok);
                    break 'attempts;
                }
            }
        }
        let (table, generation) = joined.with_context(|| {
            format!("join for ordinal {ordinal} failed after {JOIN_ATTEMPTS} attempts")
        })?;
        let membership = Membership::from_join_with_clock(
            table,
            addr,
            MembershipConfig::from_gossip(&self.cfg),
            self.net.clock(),
        )?;
        let id = membership.self_id();
        let member =
            GossipMember::from_dataset(&dataset, self.scenario.alpha, self.scenario.max_buckets)?;
        let gossip = GossipLoop::start_membership_member(
            self.cfg.clone(),
            member,
            transport,
            Arc::new(membership),
            generation,
        )?;
        Ok(SimNode {
            id,
            addr,
            ordinal,
            dataset,
            gossip,
        })
    }

    /// Crash node `id`: its links refuse, the fleet stops stepping it.
    /// Refuses to shrink the fleet below 2 alive nodes.
    fn crash_node(&mut self, id: u64, events: &mut Vec<String>) {
        if self.nodes.len() <= 2 {
            self.net
                .trace_event(&format!("fleet crash id={id} skipped (fleet floor)"));
            return;
        }
        if let Some(node) = self.nodes.remove(&id) {
            self.net.crash(node.addr);
            self.net
                .trace_event(&format!("fleet crash id={id} addr={}", node.addr));
            events.push(format!("crash id={id}"));
            self.downed.insert(
                id,
                DownedNode {
                    addr: node.addr,
                    ordinal: node.ordinal,
                },
            );
            self.oracle = None;
            // The node's gossip loop drops here: the crash is abrupt
            // from the fleet's point of view (the links already refuse).
        }
    }

    /// Recover node `id` and rejoin it through live seeds — same
    /// address, so the membership plane hands back the same member id
    /// at the next incarnation. A failed rejoin (all seeds lossy or
    /// partitioned away) leaves the node down, traced.
    fn rejoin_node(&mut self, id: u64, events: &mut Vec<String>) {
        let Some(down) = self.downed.remove(&id) else {
            return;
        };
        self.net.recover(down.addr);
        match self.start_joiner(down.ordinal) {
            Ok(node) => {
                self.net.trace_event(&format!(
                    "fleet rejoin id={} addr={} (was id={id})",
                    node.id, node.addr
                ));
                events.push(format!("rejoin id={}", node.id));
                self.insert_node(node);
            }
            Err(e) => {
                self.net.crash(down.addr);
                self.net
                    .trace_event(&format!("fleet rejoin id={id} failed: {e:#}"));
                events.push(format!("rejoin-failed id={id}"));
                self.downed.insert(id, down);
            }
        }
    }

    /// `count` brand-new members join mid-run.
    fn join_new(&mut self, count: usize, events: &mut Vec<String>) {
        for _ in 0..count {
            let ordinal = self.next_ordinal;
            self.next_ordinal += 1;
            match self.start_joiner(ordinal) {
                Ok(node) => {
                    self.net.trace_event(&format!(
                        "fleet join id={} addr={}",
                        node.id, node.addr
                    ));
                    events.push(format!("join id={}", node.id));
                    self.insert_node(node);
                }
                Err(e) => {
                    self.net
                        .trace_event(&format!("fleet join ordinal={ordinal} failed: {e:#}"));
                    events.push(format!("join-failed ordinal={ordinal}"));
                }
            }
        }
    }

    /// The directed cut isolating the lowest `frac` fraction of the
    /// alive nodes from the rest (one direction per pair — the connect
    /// check refuses on either half, TCP-like).
    fn cut_pairs(&self, frac: f64) -> Vec<(SocketAddr, SocketAddr)> {
        let addrs: Vec<SocketAddr> = self.nodes.values().map(|n| n.addr).collect();
        let island = ((addrs.len() as f64 * frac).ceil() as usize).clamp(1, addrs.len() - 1);
        let (inside, outside) = addrs.split_at(island);
        let mut pairs = Vec::with_capacity(inside.len() * outside.len());
        for &a in inside {
            for &b in outside {
                pairs.push((a, b));
            }
        }
        pairs
    }

    fn apply_partition(&mut self, frac: f64, events: &mut Vec<String>) {
        self.heal_partition(&mut Vec::new());
        let pairs = self.cut_pairs(frac);
        for &(a, b) in &pairs {
            self.net.block(a, b);
        }
        self.net.trace_event(&format!(
            "fleet partition frac={frac} cut_pairs={}",
            pairs.len()
        ));
        events.push(format!("partition frac={frac}"));
        self.partition = pairs;
    }

    fn heal_partition(&mut self, events: &mut Vec<String>) {
        if self.partition.is_empty() {
            return;
        }
        for &(a, b) in &self.partition {
            self.net.unblock(a, b);
        }
        self.net.trace_event(&format!(
            "fleet heal cut_pairs={}",
            self.partition.len()
        ));
        events.push("heal".into());
        self.partition.clear();
    }

    fn apply_flap(&mut self, round: u64, frac: f64, period: u64, events: &mut Vec<String>) {
        self.stop_flap(events);
        let pairs = self.cut_pairs(frac);
        for &(a, b) in &pairs {
            self.net.block(a, b);
        }
        self.net.trace_event(&format!(
            "fleet flap-start frac={frac} period={period} cut_pairs={}",
            pairs.len()
        ));
        events.push(format!("flap frac={frac} period={period}"));
        self.flap = Some(FlapState {
            pairs,
            period,
            started: round,
            blocked: true,
        });
    }

    fn stop_flap(&mut self, events: &mut Vec<String>) {
        if let Some(f) = self.flap.take() {
            if f.blocked {
                for &(a, b) in &f.pairs {
                    self.net.unblock(a, b);
                }
            }
            self.net.trace_event("fleet flap-stop");
            events.push("unflap".into());
        }
    }

    /// Toggle an active flap when its period elapses.
    fn tick_flap(&mut self, round: u64, events: &mut Vec<String>) {
        let Some(f) = &mut self.flap else { return };
        if round > f.started && (round - f.started) % f.period == 0 {
            f.blocked = !f.blocked;
            let now_blocked = f.blocked;
            let pairs = f.pairs.clone();
            for &(a, b) in &pairs {
                if now_blocked {
                    self.net.block(a, b);
                } else {
                    self.net.unblock(a, b);
                }
            }
            self.net
                .trace_event(&format!("fleet flap-toggle blocked={now_blocked}"));
            events.push(format!("flap-toggle blocked={now_blocked}"));
        }
    }

    /// Apply this round's churn-model transitions (edges of the
    /// precomputed online mask over the *initial* members).
    fn tick_churn(&mut self, round: u64, events: &mut Vec<String>) {
        if self.churn_schedule.is_empty() {
            return;
        }
        let mask = self.churn_schedule[(round - 1) as usize].clone();
        for (l, (&was, &is)) in self.churn_prev.iter().zip(mask.iter()).enumerate() {
            let ordinal = l as u64;
            if was && !is {
                if let Some(id) = self.id_of_alive_ordinal(ordinal) {
                    self.crash_node(id, events);
                }
            } else if !was && is {
                if let Some(id) = self.id_of_downed_ordinal(ordinal) {
                    self.rejoin_node(id, events);
                }
            }
        }
        self.churn_prev = mask;
    }

    fn id_of_alive_ordinal(&self, ordinal: u64) -> Option<u64> {
        self.nodes
            .values()
            .find(|n| n.ordinal == ordinal)
            .map(|n| n.id)
    }

    fn id_of_downed_ordinal(&self, ordinal: u64) -> Option<u64> {
        self.downed
            .iter()
            .find(|(_, d)| d.ordinal == ordinal)
            .map(|(&id, _)| id)
    }

    /// Apply the scenario events scheduled for `round`.
    fn apply_events(&mut self, round: u64, events: &mut Vec<String>) {
        let due: Vec<EventAction> = self
            .scenario
            .events
            .iter()
            .filter(|e| e.round == round)
            .map(|e| e.action)
            .collect();
        for action in due {
            match action {
                EventAction::Join(n) => self.join_new(n, events),
                EventAction::Crash(n) => {
                    // Highest ids first: keeps the bootstrap seed (and
                    // the distinguished role) for the partition events
                    // to stress instead.
                    let ids: Vec<u64> = self.nodes.keys().rev().take(n).copied().collect();
                    for id in ids {
                        self.crash_node(id, events);
                    }
                }
                EventAction::Rejoin(n) => {
                    let ids: Vec<u64> = self.downed.keys().take(n).copied().collect();
                    for id in ids {
                        self.rejoin_node(id, events);
                    }
                }
                EventAction::Partition(f) => self.apply_partition(f, events),
                EventAction::Heal => self.heal_partition(events),
                EventAction::Flap(f, p) => self.apply_flap(round, f, p, events),
                EventAction::Unflap => self.stop_flap(events),
            }
        }
        self.tick_churn(round, events);
        self.tick_flap(round, events);
    }

    /// Rebuild the oracle if the alive set changed since the last
    /// round.
    fn refresh_oracle(&mut self) {
        let key: Vec<u64> = self.nodes.keys().copied().collect();
        if self.oracle.as_ref().is_some_and(|o| o.key == key) {
            return;
        }
        let mut union: Vec<f64> = Vec::new();
        for node in self.nodes.values() {
            union.extend_from_slice(&node.dataset);
        }
        let exact = ExactQuantiles::new(&union);
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in &union {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        let bound = theorem2_bound(mn, mx, self.scenario.max_buckets);
        let tol = (2.0 * bound).max(self.scenario.alpha);
        self.oracle = Some(OracleCache { key, exact, tol });
    }

    /// Deterministic sample of alive ids for the oracle check: the
    /// extremes, the quartiles, and the median of the sorted id set.
    fn sample_ids(&self) -> Vec<u64> {
        let ids: Vec<u64> = self.nodes.keys().copied().collect();
        let n = ids.len();
        let mut picks: Vec<u64> = [0, n / 4, n / 2, 3 * n / 4, n.saturating_sub(1)]
            .iter()
            .map(|&p| ids[p.min(n - 1)])
            .collect();
        picks.dedup();
        picks
    }

    /// Worst relative value error of the sampled nodes' global views vs
    /// the exact union oracle, plus the acceptance bound.
    fn round_error(&mut self) -> (f64, f64) {
        self.refresh_oracle();
        let oracle = self.oracle.as_ref().expect("refreshed above");
        let mut worst: f64 = 0.0;
        for id in self.sample_ids() {
            let view = self.nodes[&id].gossip.view();
            for &q in &ERR_QUANTILES {
                let exact = match oracle.exact.quantile(q) {
                    Ok(v) => v,
                    Err(_) => continue,
                };
                let rel = match view.query(q) {
                    Ok(est) => (est - exact).abs() / exact.abs().max(f64::MIN_POSITIVE),
                    Err(_) => f64::INFINITY,
                };
                worst = worst.max(rel);
            }
        }
        (worst, oracle.tol)
    }

    /// Emit node `id`'s structured event lines for the round it just
    /// stepped: the server-role spans its partners recorded while it
    /// stepped (drained from the net's buffer), its own initiator
    /// spans, the round summary, and a membership event when the
    /// member table moved. The lines reuse the production encoders
    /// (`obs::export`) with the wall-clock spans stripped
    /// ([`crate::obs::RoundTrace::without_timings`]) and the virtual
    /// clock as `t_ms`, so the export is deterministic.
    fn export_round_events(&mut self, id: u64, report: &GossipRoundReport) {
        if !self.export_events {
            return;
        }
        // Serve-side spans recorded while this node stepped (its
        // partners' `server`-role lines, buffered by the net).
        self.event_lines.extend(self.net.take_serve_events());
        let node = &self.nodes[&id];
        let label = node.addr.to_string();
        let t_ms = self.net.clock().elapsed().as_millis() as u64;
        let recent = node.gossip.metrics().trace.recent(1);
        if let Some(trace) = recent.last() {
            let clean = trace.without_timings();
            for span in &clean.exchange_spans {
                self.event_lines
                    .push(encode_exchange_event(&label, t_ms, clean.round, span));
            }
            self.event_lines
                .push(encode_round_event(&label, t_ms, &clean));
        }
        if let Some(m) = &report.membership {
            if m.joined + m.suspected + m.died > 0 {
                self.event_lines.push(encode_membership_event(
                    &label,
                    t_ms,
                    report.round,
                    m.joined as u64,
                    m.suspected as u64,
                    m.died as u64,
                ));
            }
        }
    }

    /// Run the whole scenario and collapse it into a [`SimReport`].
    pub fn run(mut self) -> Result<SimReport> {
        let round_ms = Duration::from_millis(self.scenario.round_ms);
        let mut rounds: Vec<RoundLog> = Vec::with_capacity(self.scenario.rounds as usize);
        for r in 1..=self.scenario.rounds {
            self.net.set_round(r);
            self.net.clock().advance(round_ms);
            let mut events = Vec::new();
            self.apply_events(r, &mut events);
            let (mut exchanges, mut failed, mut bytes, mut mbytes) = (0usize, 0, 0, 0);
            let mut generation = 0u64;
            let ids: Vec<u64> = self.nodes.keys().copied().collect();
            for id in &ids {
                let report = self.nodes[id].gossip.step();
                exchanges += report.exchanges;
                failed += report.failed;
                bytes += report.bytes;
                mbytes += report.membership.as_ref().map_or(0, |m| m.bytes);
                generation = generation.max(report.generation);
                self.export_round_events(*id, &report);
            }
            let (max_rel_err, tol) = self.round_error();
            let within_tol = max_rel_err <= tol;
            self.net.trace_event(&format!(
                "round-summary alive={} downed={} exchanges={exchanges} \
                 failed={failed} bytes={bytes} mbytes={mbytes} \
                 gen={generation} err={max_rel_err:.6e} within={within_tol}",
                ids.len(),
                self.downed.len(),
            ));
            rounds.push(RoundLog {
                round: r,
                alive: ids.len(),
                downed: self.downed.len(),
                exchanges,
                failed,
                bytes,
                membership_bytes: mbytes,
                generation,
                max_rel_err,
                within_tol,
                events,
            });
        }
        let tol = self.oracle.as_ref().map_or(self.scenario.alpha, |o| o.tol);
        let mut converged_round = None;
        for rl in rounds.iter().rev() {
            if rl.within_tol {
                converged_round = Some(rl.round);
            } else {
                break;
            }
        }
        let final_max_rel_err = rounds.last().map_or(f64::INFINITY, |r| r.max_rel_err);
        let reference_rounds =
            (REFERENCE_C * (self.members_peak.max(2) as f64).log2()).ceil() as u64;
        Ok(SimReport {
            scenario: self.scenario.name.clone(),
            seed: self.seed,
            members_initial: self.scenario.members,
            members_peak: self.members_peak,
            rounds,
            tol,
            converged_round,
            reference_rounds,
            final_max_rel_err,
            net: self.net.stats(),
            trace: self.net.take_trace(),
            events_jsonl: self.event_lines,
        })
    }
}

/// The loop configuration a simulated node runs under: step-driven
/// (no background thread), overlay and membership knobs from the
/// scenario, one shared seed (the overlay key).
fn gossip_cfg(s: &Scenario, seed: u64) -> GossipLoopConfig {
    GossipLoopConfig {
        round_interval_ms: 0,
        fan_out: s.fan_out,
        graph: s.graph,
        seed,
        // Delta exchange baselines live in the TCP transport; the sim
        // transport always ships full frames, so the flag is moot —
        // kept off for honesty in the byte accounting.
        delta_exchanges: false,
        restart_free: s.restart_free,
        suspect_after_ms: s.suspect_after_ms,
        tombstone_ttl_ms: s.tombstone_ttl_ms,
        ..GossipLoopConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GraphKind;

    fn tiny_scenario() -> Scenario {
        Scenario {
            members: 8,
            rounds: 12,
            items_per_member: 60,
            alpha: 0.01,
            max_buckets: 256,
            ..Scenario::default()
        }
    }

    #[test]
    fn tiny_fleet_converges_to_the_union_oracle() {
        let report = SimFleet::new(tiny_scenario(), 11).unwrap().run().unwrap();
        assert_eq!(report.rounds.len(), 12);
        assert!(
            report.converged_round.is_some(),
            "final err {} vs tol {}",
            report.final_max_rel_err,
            report.tol
        );
        assert!(report.net.delivered > 0);
    }

    #[test]
    fn same_seed_same_trace() {
        let a = SimFleet::new(tiny_scenario(), 5).unwrap().run().unwrap();
        let b = SimFleet::new(tiny_scenario(), 5).unwrap().run().unwrap();
        assert_eq!(a.trace_text(), b.trace_text());
        assert_eq!(a.to_json(), b.to_json());
        let c = SimFleet::new(tiny_scenario(), 6).unwrap().run().unwrap();
        assert_ne!(a.trace_text(), c.trace_text(), "seed must matter");
    }

    #[test]
    fn crash_and_partition_events_apply() {
        let mut s = tiny_scenario();
        s.rounds = 32;
        // Fast suspicion so the crashed members turn dead (and the
        // protocol restart re-anchors the mass) well before the run
        // ends.
        s.suspect_after_ms = 1_000;
        s.events = vec![
            super::super::scenario::ScheduledEvent {
                round: 3,
                action: EventAction::Crash(2),
            },
            super::super::scenario::ScheduledEvent {
                round: 5,
                action: EventAction::Partition(0.3),
            },
            super::super::scenario::ScheduledEvent {
                round: 9,
                action: EventAction::Heal,
            },
        ];
        let report = SimFleet::new(s, 17).unwrap().run().unwrap();
        let r3 = &report.rounds[2];
        assert!(r3.events.iter().any(|e| e.starts_with("crash")), "{r3:?}");
        assert_eq!(r3.alive, 6);
        assert!(report.net.refused > 0, "partition must refuse connects");
        assert!(
            report.converged_round.is_some(),
            "post-heal convergence; final err {} vs tol {}",
            report.final_max_rel_err,
            report.tol
        );
    }

    #[test]
    fn overlay_graph_scenario_runs() {
        let mut s = tiny_scenario();
        s.members = 12;
        s.rounds = 16;
        s.graph = GraphKind::BarabasiAlbert;
        let report = SimFleet::new(s, 23).unwrap().run().unwrap();
        assert!(
            report.converged_round.is_some(),
            "BA overlay convergence; final err {} vs tol {}",
            report.final_max_rel_err,
            report.tol
        );
    }

    #[test]
    fn event_export_is_deterministic_and_joins_across_nodes() {
        use crate::obs::observe::join_event_lines;
        use crate::obs::parse_flat_json;

        let run = || {
            SimFleet::new(tiny_scenario(), 5)
                .unwrap()
                .with_event_export()
                .run()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert!(!a.events_jsonl.is_empty());
        assert_eq!(
            a.events_text(),
            b.events_text(),
            "same seed must export byte-identical event logs"
        );

        // Every line is schema-valid flat JSON; round events appear
        // once per alive node per round (8 members × 12 rounds, no
        // churn in the tiny scenario).
        let mut rounds = 0usize;
        let mut exchanges = 0usize;
        for line in &a.events_jsonl {
            let obj = parse_flat_json(line).unwrap_or_else(|| panic!("bad line: {line}"));
            match obj["event"].as_str().unwrap() {
                "round" => {
                    rounds += 1;
                    assert!(obj.contains_key("restart_cause"), "{line}");
                    // Wall-clock spans are stripped for determinism.
                    assert_eq!(obj["total_us"].as_u64(), Some(0), "{line}");
                }
                "exchange" => {
                    exchanges += 1;
                    assert!(obj["trace_id"].as_str().is_some(), "{line}");
                    assert!(
                        matches!(obj["role"].as_str(), Some("initiator" | "server")),
                        "{line}"
                    );
                }
                "membership" => {}
                other => panic!("unexpected event kind {other}"),
            }
        }
        assert_eq!(rounds, 8 * 12, "one round event per node per round");
        assert!(exchanges > 0);

        // The tentpole property, in simulation: initiator and server
        // lines carry the same wire trace id and join into consistent
        // causal records.
        let joined = join_event_lines(a.events_jsonl.iter().map(|s| s.as_str()));
        assert!(!joined.is_empty());
        let consistent = joined.iter().filter(|c| c.consistent()).count();
        assert!(
            consistent > 0,
            "no exchange joined across both ends out of {}",
            joined.len()
        );

        // Without the opt-in, the export stays empty (and costs
        // nothing).
        let plain = SimFleet::new(tiny_scenario(), 5).unwrap().run().unwrap();
        assert!(plain.events_jsonl.is_empty());
    }

    #[test]
    fn json_log_is_well_formed_enough() {
        let report = SimFleet::new(tiny_scenario(), 3).unwrap().run().unwrap();
        let json = report.to_json();
        assert!(json.starts_with("{\"scenario\":"));
        assert!(json.ends_with("}\n"));
        // One per round object ("converged_round" has no quote before
        // the substring, so it doesn't count).
        assert_eq!(json.matches("\"round\":").count(), 12);
    }
}
