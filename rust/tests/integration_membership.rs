//! Integration: gossip-based membership & live churn (ISSUE 5).
//!
//! Acceptance:
//! * a loopback-TCP fleet built **without a static address book** —
//!   node 0 bootstraps the membership plane, everyone else enters
//!   through the `dudd-join` handshake — converges to the sequential
//!   union sketch within α while a 4th node **joins after 3 rounds**
//!   and one member is **killed mid-run**, with no manual restart
//!   anywhere; under the restart-free churn rules (ISSUE 9,
//!   `docs/PROTOCOL.md` §10) the join leaves the generation at 1 and
//!   only the death re-anchors;
//! * a node **rejoins at its own address mid-run** (same member id,
//!   incarnation + 1): the fleet converges within α and no survivor's
//!   `GossipRoundReport` ever bumps the generation;
//! * the survivors' member tables are **byte-identical** at quiescence
//!   (canonical encoding), with the crashed member held as a dead
//!   tombstone;
//! * a simulated churn schedule (`churn::ChurnModel`) **replays against
//!   a real TCP fleet**: the model decides which member crashes and
//!   when, including the distinguished member id 0 — the `q̃ = 1` role
//!   re-anchors on the lowest surviving id and the mass stays exact;
//! * a static address-book node refuses membership traffic with
//!   `NoMembership` instead of serving it.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

mod common;

use duddsketch::churn::{ChurnKind, ChurnModel};
use duddsketch::config::ServiceConfig;
use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::metrics::relative_error;
use duddsketch::prelude::*;
use duddsketch::rng::default_rng;
use std::net::SocketAddr;
use std::time::Duration;

const QS: [f64; 3] = [0.5, 0.9, 0.99];

fn churn_cfg(suspect_ms: u64) -> ServiceConfig {
    let mut c = ServiceConfig::default();
    c.shards = 2;
    c.batch_size = 256;
    c.gossip.round_interval_ms = 0; // tests are the clock
    c.gossip.exchange_deadline_ms = 2_000;
    c.gossip.suspect_after_ms = suspect_ms;
    c
}

/// Build one membership node: bootstrap (no seed) or join via `seed`.
fn membership_node(cfg: &ServiceConfig, seed: Option<SocketAddr>) -> Node {
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    let t = TcpTransport::bind_with("127.0.0.1:0", opts).unwrap();
    let b = Node::builder().config(cfg.clone()).transport(t);
    let b = match seed {
        None => b.membership_bootstrap(),
        Some(a) => b.join(a),
    };
    b.build().unwrap()
}

fn ingest(node: &Node, data: &[f64]) {
    let mut w = node.writer();
    w.insert_batch(data);
    w.flush();
    node.flush();
}

/// Sweep all nodes until every node's view is converged on the expected
/// union total at one shared generation, under a bounded deadline. Each
/// probe is one full sweep; the polling tick between probes lets the
/// wall-clock suspicion and anti-entropy clocks advance. Returns the
/// number of sweeps it took.
fn converge(fleet: &[Node], total: f64, deadline: Duration) -> usize {
    let sweeps = common::wait_until(deadline, || {
        for n in fleet {
            n.step();
        }
        let views: Vec<_> = fleet
            .iter()
            .map(|n| n.global_view().expect("gossip enabled"))
            .collect();
        let gen0 = views[0].generation();
        views.iter().all(|v| {
            v.generation() == gen0 && v.converged() && v.estimated_total() == total
        })
    });
    if let Some(sweeps) = sweeps {
        return sweeps;
    }
    let states: Vec<String> = fleet
        .iter()
        .map(|n| {
            let v = n.global_view().unwrap();
            let (a, s, d) = n.membership().unwrap().counts();
            format!(
                "gen={} total={} converged={} view={a}/{s}/{d}",
                v.generation(),
                v.estimated_total(),
                v.converged()
            )
        })
        .collect();
    panic!("membership fleet did not converge within {deadline:?}: {states:?}");
}

fn assert_views_match(fleet: &[Node], seq: &UddSketch, peers: f64, total: f64) {
    for (k, node) in fleet.iter().enumerate() {
        let v = node.global_view().unwrap();
        assert_eq!(v.estimated_peers(), peers, "node {k} fleet size");
        assert_eq!(v.estimated_total(), total, "node {k} union length");
        for q in QS {
            let est = v.query(q).unwrap();
            let truth = seq.quantile(q).unwrap();
            let re = relative_error(est, truth);
            assert!(
                re <= seq.alpha() + 1e-9,
                "node {k} q={q}: view {est} vs sequential {truth} \
                 (re {re} > alpha {})",
                seq.alpha()
            );
        }
    }
}

/// The acceptance scenario: a 4-node fleet assembled by join handshakes
/// where the 4th node joins after 3 live rounds and another member is
/// killed mid-run. The survivors re-converge to the sequential union of
/// the SURVIVING streams within α, and their member tables are
/// byte-identical at quiescence.
#[test]
fn node_joins_after_three_rounds_and_crash_survivors_reconverge() {
    let items = 2_000;
    let master = default_rng(42);
    let datasets: Vec<Vec<f64>> = (0..4)
        .map(|i| peer_dataset(DatasetKind::Exponential, i, items, &master))
        .collect();

    // Bootstrap node 0; nodes 1–2 join through it. Ids are assigned by
    // the handshake in join order.
    let cfg = churn_cfg(200);
    let mut fleet = vec![membership_node(&cfg, None)];
    let seed_addr = fleet[0].listen_addr().unwrap();
    for _ in 1..3 {
        fleet.push(membership_node(&cfg, Some(seed_addr)));
    }
    for (k, node) in fleet.iter().enumerate() {
        let m = node.membership().expect("membership on");
        assert_eq!(m.self_id(), k as u64, "join handshake assigns sequential ids");
        ingest(node, &datasets[k]);
    }

    // Three live rounds before anyone else shows up.
    for _ in 0..3 {
        for n in &fleet {
            n.step();
        }
        std::thread::sleep(Duration::from_millis(10));
    }

    // A 4th node joins the RUNNING fleet — via node 1, not the
    // bootstrap seed (any member serves the handshake).
    let joiner = membership_node(&cfg, Some(fleet[1].listen_addr().unwrap()));
    assert_eq!(joiner.membership().unwrap().self_id(), 3);
    ingest(&joiner, &datasets[3]);
    fleet.push(joiner);

    // The whole 4-node fleet converges on the full union: the join
    // spread by anti-entropy and the joiner's stream entered the view
    // WITHOUT a protocol restart — restart-free joins are admitted into
    // the current generation with q̃ = 0 (docs/PROTOCOL.md §10).
    let mut seq_all: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
    for d in &datasets {
        seq_all.extend(d);
    }
    converge(&fleet, (4 * items) as f64, Duration::from_secs(60));
    assert_views_match(&fleet, &seq_all, 4.0, (4 * items) as f64);
    let gen_joined = fleet[0].global_view().unwrap().generation();
    assert_eq!(
        gen_joined, 1,
        "restart-free: the join must not have restarted the protocol"
    );

    // Kill member 2 mid-run — no restart anywhere. Survivors suspect it
    // on failed exchanges, declare it dead, bump the generation, and
    // re-anchor the union on the surviving streams.
    let victim = fleet.remove(2);
    victim.shutdown();
    let mut seq: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
    for &d in &[0usize, 1, 3] {
        seq.extend(&datasets[d]);
    }
    converge(&fleet, (3 * items) as f64, Duration::from_secs(60));
    assert_views_match(&fleet, &seq, 3.0, (3 * items) as f64);
    assert!(
        fleet[0].global_view().unwrap().generation() > gen_joined,
        "the death must have restarted the protocol"
    );

    // Membership acceptance: every survivor holds the same 4-entry
    // table byte for byte, with member 2 a dead tombstone.
    for (k, node) in fleet.iter().enumerate() {
        let table = node.membership().unwrap().table();
        assert_eq!(table.len(), 4, "node {k} table size");
        assert_eq!(
            table.get(2).unwrap().status,
            MemberStatus::Dead,
            "node {k} must hold member 2's tombstone"
        );
        assert_eq!(table.distinguished_id(), Some(0));
    }
    let encoded: Vec<Vec<u8>> = fleet
        .iter()
        .map(|n| n.membership().unwrap().encoded_table())
        .collect();
    assert!(
        encoded.iter().all(|e| e == &encoded[0]),
        "surviving member tables must be byte-identical at quiescence"
    );

    for node in fleet {
        node.shutdown();
    }
}

/// A simulated churn schedule replayed against a real TCP fleet: the
/// `ChurnModel` (Fail&Stop, §7.2) decides which member crashes and
/// when; the fleet executes the crash live. The scheduled victim is
/// whatever the model says — when it is member 0, this also exercises
/// the dynamic distinguished-peer rule (`q̃ = 1` re-anchors on the
/// lowest surviving id).
#[test]
fn failstop_schedule_replays_against_tcp_fleet() {
    let items = 1_200;
    let peers = 3usize;
    let master = default_rng(7);
    let datasets: Vec<Vec<f64>> = (0..peers)
        .map(|i| peer_dataset(DatasetKind::Uniform, i, items, &master))
        .collect();

    // The schedule is a pure function of the model: the replay below and
    // any future re-run pick the identical crash point.
    let model = ChurnModel::new(ChurnKind::FailStop, peers, &master);
    let (crash_round, victim_id) = model
        .first_failure(800, peers)
        .expect("fail&stop over 800 rounds fails someone");
    assert_eq!(
        (crash_round, victim_id),
        model.first_failure(800, peers).unwrap(),
        "schedule must be deterministic"
    );

    let cfg = churn_cfg(150);
    let mut fleet = vec![membership_node(&cfg, None)];
    let seed_addr = fleet[0].listen_addr().unwrap();
    for _ in 1..peers {
        fleet.push(membership_node(&cfg, Some(seed_addr)));
    }
    for (k, node) in fleet.iter().enumerate() {
        ingest(node, &datasets[k]);
    }

    // Replay: run the schedule's rounds (capped — pre-crash rounds are
    // all-online, so compressing them changes nothing the fleet can
    // observe), then crash the scheduled victim.
    for _ in 0..crash_round.min(5) {
        for n in &fleet {
            n.step();
        }
    }
    let victim = fleet.remove(victim_id);
    victim.shutdown();

    let survivors: Vec<usize> = (0..peers).filter(|&l| l != victim_id).collect();
    let mut seq: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
    for &d in &survivors {
        seq.extend(&datasets[d]);
    }
    let total = (survivors.len() * items) as f64;
    converge(&fleet, total, Duration::from_secs(60));
    assert_views_match(&fleet, &seq, survivors.len() as f64, total);

    // The distinguished role sits on the lowest SURVIVING id — the
    // whole point when the schedule kills member 0.
    let expect_distinguished = survivors[0] as u64;
    for node in &fleet {
        let table = node.membership().unwrap().table();
        assert_eq!(table.distinguished_id(), Some(expect_distinguished));
        assert_eq!(
            table.get(victim_id as u64).unwrap().status,
            MemberStatus::Dead
        );
    }
    for node in fleet {
        node.shutdown();
    }
}

/// Restart-free same-address rejoin (ISSUE 9): a node joins a running
/// fleet, goes down, and comes back at the SAME socket address before
/// anyone suspects it. The `dudd-join` handshake hands its member id
/// back at the next incarnation instead of minting a new id, the fleet
/// converges on the full union within α, and — the tentpole contract —
/// no node's `GossipRoundReport` ever leaves generation 1: a live
/// incarnation advance is not a view change (`docs/PROTOCOL.md` §10).
///
/// The first incarnation is shut down before any survivor runs a round,
/// so nothing ever connects TO its listener: `TcpTransport` binds
/// without `SO_REUSEADDR`, and a served connection's TIME_WAIT would
/// make the same-port rebind flaky. A never-accepted listener leaves no
/// socket state behind, so the second bind is deterministic. The fast
/// crash also loses no mass — a restart-free joiner enters with
/// q̃ = 0, so the union totals below stay exact.
#[test]
fn same_address_rejoin_bumps_incarnation_not_generation() {
    let items = 1_500;
    let master = default_rng(93);
    let datasets: Vec<Vec<f64>> = (0..3)
        .map(|i| peer_dataset(DatasetKind::Uniform, i, items, &master))
        .collect();

    // Suspicion is deliberately slack: the blink between shutdown and
    // rejoin must never be long enough to declare the victim dead — a
    // death WOULD re-anchor, and this test pins the path that must not.
    let cfg = churn_cfg(60_000);
    let mut fleet = vec![membership_node(&cfg, None)];
    let seed_addr = fleet[0].listen_addr().unwrap();
    fleet.push(membership_node(&cfg, Some(seed_addr)));
    for (k, node) in fleet.iter().enumerate() {
        ingest(node, &datasets[k]);
    }
    converge(&fleet, (2 * items) as f64, Duration::from_secs(60));

    // First incarnation: join the running fleet at an OS-assigned
    // address, then go down immediately (a fast restart, e.g. a process
    // respawn under a supervisor).
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    let first = Node::builder()
        .config(cfg.clone())
        .transport(TcpTransport::bind_with("127.0.0.1:0", opts.clone()).unwrap())
        .join(seed_addr)
        .build()
        .unwrap();
    let victim_addr = first.listen_addr().unwrap();
    assert_eq!(first.membership().unwrap().self_id(), 2);
    first.shutdown();

    // Second incarnation: the same address, so the handshake hands back
    // member id 2 at incarnation 2 instead of minting id 3.
    let rejoined = Node::builder()
        .config(cfg.clone())
        .transport(TcpTransport::bind_with(victim_addr, opts).unwrap())
        .join(seed_addr)
        .build()
        .unwrap();
    {
        let m = rejoined.membership().unwrap();
        assert_eq!(m.self_id(), 2, "same address must hand the member id back");
        let table = m.table();
        let entry = table.get(2).unwrap();
        assert_eq!(entry.incarnation, 2, "rejoin advances the incarnation");
        assert_eq!(entry.status, MemberStatus::Alive);
    }
    ingest(&rejoined, &datasets[2]);
    fleet.push(rejoined);

    // Converge on the full 3-stream union, inspecting every round
    // report on the way: the rejoin must never bump the generation or
    // restart the protocol on ANY node — the epoch advance from the
    // rejoined node's ingest is carried in place, not reseeded.
    let total = (3 * items) as f64;
    let sweeps = common::wait_until(Duration::from_secs(60), || {
        for (k, n) in fleet.iter().enumerate() {
            let r = n.step().expect("gossip enabled");
            assert_eq!(
                r.generation, 1,
                "node {k}: a same-address rejoin must not bump the generation"
            );
            assert!(
                r.restart_cause.is_none(),
                "node {k}: no round may restart the protocol: {:?}",
                r.restart_cause
            );
        }
        let views_ok = fleet.iter().all(|n| {
            let v = n.global_view().unwrap();
            v.generation() == 1 && v.converged() && v.estimated_total() == total
        });
        let tables_ok = fleet.iter().all(|n| {
            let table = n.membership().unwrap().table();
            table.len() == 3
                && table
                    .get(2)
                    .is_some_and(|e| e.incarnation == 2 && e.status == MemberStatus::Alive)
        });
        views_ok && tables_ok
    });
    assert!(
        sweeps.is_some(),
        "fleet did not converge after the same-address rejoin"
    );

    let mut seq_all: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
    for d in &datasets {
        seq_all.extend(d);
    }
    assert_views_match(&fleet, &seq_all, 3.0, total);
    for node in &fleet {
        let table = node.membership().unwrap().table();
        assert_eq!(table.distinguished_id(), Some(0));
    }
    for node in fleet {
        node.shutdown();
    }
}

/// Membership traffic at a static address-book node draws the
/// `NoMembership` reject (and the static node keeps serving data
/// exchanges untouched).
#[test]
fn static_fleet_rejects_membership_traffic() {
    let mut cfg = ServiceConfig::default();
    cfg.shards = 1;
    cfg.gossip.round_interval_ms = 0;
    cfg.gossip.exchange_deadline_ms = 1_000;
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    // A static node (remote-peer list, no membership plane).
    let placeholder = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let static_node = Node::builder()
        .config(cfg.clone())
        .self_index(0)
        .transport(TcpTransport::bind_with("127.0.0.1:0", opts.clone()).unwrap())
        .remote_peer(placeholder)
        .build()
        .unwrap();
    let addr = static_node.listen_addr().unwrap();

    // A would-be joiner is refused with NoMembership...
    let client = TcpTransport::bind_with("127.0.0.1:0", opts).unwrap();
    let err = client.join_remote(addr).unwrap_err();
    assert!(matches!(err, TransportError::NoMembership), "{err:?}");
    // ...and a membership push is too.
    let err = client
        .exchange_membership(addr, 1, &MemberTable::new())
        .unwrap_err();
    assert!(matches!(err, TransportError::NoMembership), "{err:?}");

    static_node.shutdown();
}

/// Builder guard rails: dynamic membership needs a serving transport and
/// refuses a mixed static/dynamic configuration.
#[test]
fn membership_builder_rejects_bad_wiring() {
    // Connect-only transport: the joiner would be unreachable.
    let err = Node::builder()
        .shards(1)
        .transport(TcpTransport::connect_only(Duration::from_millis(100)).unwrap())
        .membership_bootstrap()
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("serving transport"), "{err:#}");

    // No transport at all.
    let err = Node::builder()
        .shards(1)
        .membership_bootstrap()
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("remote transport"), "{err:#}");

    // Static member list + membership: mutually exclusive.
    let t = TcpTransport::bind("127.0.0.1:0", Duration::from_millis(100)).unwrap();
    let err = Node::builder()
        .shards(1)
        .transport(t)
        .membership_bootstrap()
        .remote_peer("127.0.0.1:9".parse().unwrap())
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("mutually exclusive"), "{err:#}");

    // Bootstrap and join at once: ambiguous.
    let t = TcpTransport::bind("127.0.0.1:0", Duration::from_millis(100)).unwrap();
    let err = Node::builder()
        .shards(1)
        .transport(t)
        .membership_bootstrap()
        .join("127.0.0.1:9".parse().unwrap())
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("choose one"), "{err:#}");

    // No seed answering: the join fails with the seed named.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let mut cfg = ServiceConfig::default();
    cfg.shards = 1;
    cfg.gossip.exchange_deadline_ms = 200;
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    let t = TcpTransport::bind_with("127.0.0.1:0", opts).unwrap();
    let err = Node::builder()
        .config(cfg)
        .transport(t)
        .join(dead)
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("dudd-join"), "{err:#}");
}
