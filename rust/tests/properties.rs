//! Property-based tests on the coordinator's invariants (DESIGN.md §7),
//! via the in-tree testkit (proptest is unavailable offline).

use duddsketch::gossip::PeerState;
use duddsketch::metrics::relative_error;
use duddsketch::rng::Rng;
use duddsketch::sketch::{
    apply_delta, decode_exchange, decode_sketch, delta_payload, delta_wire_size,
    encode_exchange_delta_push, encode_exchange_push, encode_exchange_reply, encode_sketch,
    peer_state_fingerprint, theorem2_bound, DdSketch, ExactQuantiles, ExchangeFrame,
    SparseStore, Store, UddSketch,
};
use duddsketch::util::testkit::{forall, forall_vec, gen};

const SEED: u64 = 0xD0DD;

/// Invariant 1: every quantile of every dataset is answered within the
/// sketch's *current* α (which accounts for collapses).
#[test]
fn prop_relative_accuracy_all_quantiles() {
    forall_vec(
        "udd-relative-accuracy",
        SEED,
        48,
        |r| gen::log_uniform_vec(r, 4000, 6.0, 4.0),
        |xs| {
            let mut s: UddSketch = UddSketch::new(0.01, 128).unwrap();
            s.extend(xs);
            let exact = ExactQuantiles::new(xs);
            for q in [0.0, 0.01, 0.1, 0.5, 0.9, 0.99, 1.0] {
                let est = s.quantile(q).map_err(|e| e.to_string())?;
                let tru = exact.quantile(q).map_err(|e| e.to_string())?;
                let re = relative_error(est, tru);
                if re > s.alpha() + 1e-9 {
                    return Err(format!(
                        "q={q}: re {re} > alpha {} (collapses {})",
                        s.alpha(),
                        s.collapses()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Invariant 3: the post-collapse α never exceeds the Theorem 2 bound for
/// the observed span.
#[test]
fn prop_theorem2_bound_holds() {
    forall_vec(
        "theorem2",
        SEED + 1,
        48,
        |r| gen::log_uniform_vec(r, 3000, 8.0, 5.0),
        |xs| {
            let mut s: UddSketch = UddSketch::new(0.001, 64).unwrap();
            s.extend(xs);
            let (mn, mx) = xs
                .iter()
                .fold((f64::MAX, f64::MIN), |(a, b), &x| (a.min(x), b.max(x)));
            let bound = theorem2_bound(mn, mx, 64);
            if s.alpha() > bound + 1e-9 {
                return Err(format!("alpha {} > bound {bound}", s.alpha()));
            }
            Ok(())
        },
    );
}

/// Invariant 4: permutation invariance — insertion order never changes the
/// resulting sketch.
#[test]
fn prop_permutation_invariance() {
    forall(
        "permutation-invariance",
        SEED + 2,
        32,
        |r| {
            let xs = gen::log_uniform_vec(r, 1500, 5.0, 3.0);
            let mut ys = xs.clone();
            r.shuffle(&mut ys);
            (xs, ys)
        },
        |(xs, ys)| {
            let mut a: UddSketch = UddSketch::new(0.005, 64).unwrap();
            let mut b: UddSketch = UddSketch::new(0.005, 64).unwrap();
            a.extend(xs);
            b.extend(ys);
            if a.collapses() != b.collapses() {
                return Err(format!(
                    "collapse depth differs: {} vs {}",
                    a.collapses(),
                    b.collapses()
                ));
            }
            let ea = a.positive_store().entries();
            let eb = b.positive_store().entries();
            if ea.len() != eb.len()
                || ea
                    .iter()
                    .zip(&eb)
                    .any(|((i, c), (j, d))| i != j || (c - d).abs() > 1e-9)
            {
                return Err("stores differ".into());
            }
            Ok(())
        },
    );
}

/// Invariant: mergeability — merge(S(D1), S(D2)) answers exactly like
/// S(D1 ⊎ D2) for every quantile.
#[test]
fn prop_merge_equals_union() {
    forall(
        "merge-union",
        SEED + 3,
        32,
        |r| {
            (
                gen::log_uniform_vec(r, 1200, 4.0, 2.0),
                gen::log_uniform_vec(r, 1200, 4.0, 5.0),
                gen::quantile(r),
            )
        },
        |(d1, d2, q)| {
            let mut s1: UddSketch = UddSketch::new(0.01, 64).unwrap();
            let mut s2: UddSketch = UddSketch::new(0.01, 64).unwrap();
            s1.extend(d1);
            s2.extend(d2);
            s1.merge(&s2).map_err(|e| e.to_string())?;
            let mut su: UddSketch = UddSketch::new(0.01, 64).unwrap();
            su.extend(d1);
            su.extend(d2);
            let a = s1.quantile(*q).map_err(|e| e.to_string())?;
            let b = su.quantile(*q).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("q={q}: merged {a} != union {b}"));
            }
            Ok(())
        },
    );
}

/// Invariant: the turnstile model — inserting then deleting a batch
/// restores the prior answers exactly.
#[test]
fn prop_turnstile_roundtrip() {
    forall(
        "turnstile",
        SEED + 4,
        32,
        |r| {
            (
                gen::uniform_vec(r, 800, 1.0, 1e4),
                gen::uniform_vec(r, 200, 1.0, 1e4),
            )
        },
        |(base, extra)| {
            let mut s: UddSketch = UddSketch::new(0.01, 4096).unwrap();
            s.extend(base);
            let before: Vec<(i64, f64)> = s.positive_store().entries();
            for &x in extra {
                s.insert(x);
            }
            for &x in extra {
                s.delete(x);
            }
            if s.positive_store().entries() != before {
                return Err("store not restored after delete".into());
            }
            Ok(())
        },
    );
}

/// Invariant 5: gossip averaging conserves the per-bucket mass and the
/// scalar masses for any exchange sequence.
#[test]
fn prop_gossip_exchange_conserves_mass() {
    forall(
        "gossip-mass",
        SEED + 5,
        24,
        |r| {
            let peers = 2 + r.index(6);
            let data: Vec<Vec<f64>> = (0..peers)
                .map(|_| gen::uniform_vec(r, 300, 1.0, 1e3))
                .collect();
            let exchanges: Vec<(usize, usize)> = (0..10)
                .map(|_| {
                    let a = r.index(peers);
                    let mut b = r.index(peers);
                    while b == a {
                        b = r.index(peers);
                    }
                    (a, b)
                })
                .collect();
            (data, exchanges)
        },
        |(data, exchanges)| {
            let mut states: Vec<PeerState> = data
                .iter()
                .enumerate()
                .map(|(i, d)| PeerState::init(i, d, 0.01, 64).unwrap())
                .collect();
            let total_c: f64 = states.iter().map(|s| s.sketch.count()).sum();
            let total_q: f64 = states.iter().map(|s| s.q_tilde).sum();
            for &(a, b) in exchanges {
                let merged =
                    PeerState::averaged(&states[a], &states[b]).map_err(|e| e.to_string())?;
                states[a] = PeerState {
                    id: a,
                    sketch: merged.sketch.clone(),
                    n_tilde: merged.n_tilde,
                    q_tilde: merged.q_tilde,
                };
                states[b] = PeerState { id: b, ..merged };
            }
            let after_c: f64 = states.iter().map(|s| s.sketch.count()).sum();
            let after_q: f64 = states.iter().map(|s| s.q_tilde).sum();
            if (total_c - after_c).abs() > 1e-6 * total_c.max(1.0) {
                return Err(format!("count mass {total_c} -> {after_c}"));
            }
            if (total_q - after_q).abs() > 1e-9 {
                return Err(format!("q mass {total_q} -> {after_q}"));
            }
            Ok(())
        },
    );
}

/// Invariant: quantile answers are monotone in q.
#[test]
fn prop_quantile_monotone() {
    forall_vec(
        "monotone",
        SEED + 6,
        32,
        |r| gen::log_uniform_vec(r, 2000, 5.0, 3.0),
        |xs| {
            let mut s: UddSketch = UddSketch::new(0.01, 64).unwrap();
            s.extend(xs);
            let mut prev = f64::MIN;
            for i in 0..=20 {
                let q = i as f64 / 20.0;
                let est = s.quantile(q).map_err(|e| e.to_string())?;
                if est < prev {
                    return Err(format!("q={q}: {est} < prev {prev}"));
                }
                prev = est;
            }
            Ok(())
        },
    );
}

/// Invariant: the wire codec roundtrips any turnstile history bit-exactly
/// — inserts, deletes, negatives, zeros, and collapse lineages. The
/// service snapshot path (and every gossip frame) leans on this.
#[test]
fn prop_codec_roundtrip_turnstile() {
    forall(
        "codec-turnstile",
        SEED + 8,
        32,
        |r| {
            let xs = gen::log_uniform_vec(r, 2000, 6.0, 3.0);
            let n_del = r.index(xs.len());
            (xs, n_del)
        },
        |(xs, n_del)| {
            let mut s: UddSketch<SparseStore> = UddSketch::new(0.001, 64).unwrap();
            s.extend(xs);
            s.insert(0.0);
            s.insert(-7.25);
            for &x in &xs[..*n_del] {
                s.delete(x);
            }
            let buf = encode_sketch(&s);
            let d: UddSketch<SparseStore> =
                decode_sketch(&buf).map_err(|e| e.to_string())?;
            if d.collapses() != s.collapses() {
                return Err(format!(
                    "collapse depth {} != {}",
                    d.collapses(),
                    s.collapses()
                ));
            }
            if d.zero_weight() != s.zero_weight() {
                return Err("zero weight differs".into());
            }
            if d.positive_store().entries() != s.positive_store().entries() {
                return Err("positive entries differ".into());
            }
            if d.negative_store().entries() != s.negative_store().entries() {
                return Err("negative entries differ".into());
            }
            for q in [0.01, 0.5, 0.99] {
                let a = d.quantile(q).map_err(|e| e.to_string())?;
                let b = s.quantile(q).map_err(|e| e.to_string())?;
                if a != b {
                    return Err(format!("q={q}: decoded {a} != original {b}"));
                }
            }
            Ok(())
        },
    );
}

/// Invariant: merging remains exact in the turnstile model — sketches
/// carrying deletes merge (plain and gossip-weighted) to exactly the
/// union-processed state. The service's epoch fold is this operation.
#[test]
fn prop_merge_weighted_under_turnstile() {
    forall(
        "merge-turnstile",
        SEED + 9,
        24,
        |r| {
            let d1 = gen::uniform_vec(r, 1000, 1.0, 1e4);
            let d2 = gen::uniform_vec(r, 1000, 1.0, 1e4);
            let k1 = r.index(d1.len());
            let k2 = r.index(d2.len());
            (d1, d2, k1, k2)
        },
        |(d1, d2, k1, k2)| {
            // Budget large enough that no collapse occurs: exact equality
            // is the contract here (collapse-timing differences are
            // covered by the insert-only merge property above).
            let build = |data: &[f64], dels: usize| {
                let mut s: UddSketch = UddSketch::new(0.01, 4096).unwrap();
                s.extend(data);
                for &x in &data[..dels] {
                    s.delete(x);
                }
                s
            };
            let s1 = build(d1, *k1);
            let s2 = build(d2, *k2);

            let mut merged = s1.clone();
            merged.merge(&s2).map_err(|e| e.to_string())?;

            let mut union: UddSketch = UddSketch::new(0.01, 4096).unwrap();
            union.extend(d1);
            union.extend(d2);
            for &x in &d1[..*k1] {
                union.delete(x);
            }
            for &x in &d2[..*k2] {
                union.delete(x);
            }

            if (merged.count() - union.count()).abs() > 1e-9 {
                return Err(format!(
                    "count {} != union {}",
                    merged.count(),
                    union.count()
                ));
            }
            let em = merged.positive_store().entries();
            let eu = union.positive_store().entries();
            if em.len() != eu.len()
                || em
                    .iter()
                    .zip(&eu)
                    .any(|((i, c), (j, d))| i != j || (c - d).abs() > 1e-9)
            {
                return Err("merged entries differ from union".into());
            }
            for q in [0.01, 0.5, 0.99] {
                let a = merged.quantile(q).map_err(|e| e.to_string())?;
                let b = union.quantile(q).map_err(|e| e.to_string())?;
                if a != b {
                    return Err(format!("q={q}: merged {a} != union {b}"));
                }
            }

            // Gossip averaging on turnstile state: (0.5, 0.5) halves every
            // bucket of the union exactly.
            let mut avg = s1.clone();
            avg.merge_weighted(&s2, 0.5, 0.5).map_err(|e| e.to_string())?;
            if (avg.count() - 0.5 * union.count()).abs() > 1e-9 {
                return Err(format!(
                    "avg count {} != half union {}",
                    avg.count(),
                    0.5 * union.count()
                ));
            }
            let ea = avg.positive_store().entries();
            if ea.len() != eu.len()
                || ea
                    .iter()
                    .zip(&eu)
                    .any(|((i, c), (j, d))| i != j || (c - 0.5 * d).abs() > 1e-9)
            {
                return Err("averaged entries are not half the union".into());
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 3): exchange frames — the transport's on-wire
/// messages — roundtrip any turnstile peer state bit-exactly (generation,
/// id, scalars, every bucket), for both push and reply kinds.
#[test]
fn prop_exchange_frame_roundtrip() {
    forall(
        "exchange-roundtrip",
        SEED + 10,
        24,
        |r| {
            let xs = gen::log_uniform_vec(r, 1500, 5.0, 3.0);
            let id = r.index(64);
            let generation = r.index(1 << 20) as u64;
            let n_del = r.index(xs.len() / 2);
            (xs, id, generation, n_del)
        },
        |(xs, id, generation, n_del)| {
            let mut st = PeerState::init(*id, xs, 0.001, 64).map_err(|e| e.to_string())?;
            for &x in &xs[..*n_del] {
                st.sketch.delete(x);
            }
            st.n_tilde = xs.len() as f64 - *n_del as f64;
            for buf in [
                encode_exchange_push(*generation, &st),
                encode_exchange_reply(*generation, &st),
            ] {
                let frame = decode_exchange(&buf).map_err(|e| e.to_string())?;
                let (gen_out, out) = match frame {
                    ExchangeFrame::Push { generation, state } => (generation, state),
                    ExchangeFrame::Reply { generation, state } => (generation, state),
                    other => return Err(format!("wrong kind decoded: {other:?}")),
                };
                if gen_out != *generation {
                    return Err(format!("generation {gen_out} != {generation}"));
                }
                if out.id != *id {
                    return Err(format!("id {} != {id}", out.id));
                }
                if out.n_tilde.to_bits() != st.n_tilde.to_bits()
                    || out.q_tilde.to_bits() != st.q_tilde.to_bits()
                {
                    return Err("scalars differ".into());
                }
                if out.sketch.positive_store().entries()
                    != st.sketch.positive_store().entries()
                {
                    return Err("positive entries differ".into());
                }
                if out.sketch.collapses() != st.sketch.collapses() {
                    return Err("collapse depth differs".into());
                }
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 3): no corruption of an exchange frame decodes —
/// truncation at every offset fails, and flipping the magic, version, or
/// kind byte is rejected. A malformed frame must never be mistaken for a
/// valid partner state (the transport's §7.2 cancellation depends on it).
#[test]
fn prop_exchange_frame_rejects_corruption() {
    forall(
        "exchange-corruption",
        SEED + 11,
        16,
        |r| {
            let xs = gen::uniform_vec(r, 400, 1.0, 1e4);
            let cut_unit = r.next_f64();
            (xs, cut_unit)
        },
        |(xs, cut_unit)| {
            let st = PeerState::init(1, xs, 0.01, 64).map_err(|e| e.to_string())?;
            let buf = encode_exchange_push(3, &st);

            // Truncation at a random offset (and the structural edges).
            let random_cut = ((buf.len() - 1) as f64 * cut_unit) as usize;
            for cut in [0usize, 4, 5, 6, 13, random_cut, buf.len() - 1] {
                if decode_exchange(&buf[..cut]).is_ok() {
                    return Err(format!("truncation at {cut} decoded"));
                }
            }
            // Header corruption: magic, version, kind.
            for (pos, val) in [(0usize, b'X'), (4, 77u8), (5, 9u8)] {
                let mut bad = buf.clone();
                bad[pos] = val;
                if decode_exchange(&bad).is_ok() {
                    return Err(format!("corrupt byte {pos} decoded"));
                }
            }
            Ok(())
        },
    );
}

/// DDSketch (the baseline) keeps its α guarantee on the top quantile even
/// under collapse — the property UDDSketch extends to the whole range.
#[test]
fn prop_ddsketch_high_quantile_guarantee() {
    forall_vec(
        "dd-high-q",
        SEED + 7,
        32,
        |r| gen::log_uniform_vec(r, 3000, 6.0, 4.0),
        |xs| {
            let mut s: DdSketch = DdSketch::new(0.01, 64).unwrap();
            s.extend(xs);
            let exact = ExactQuantiles::new(xs);
            let est = s.quantile(1.0).map_err(|e| e.to_string())?;
            let tru = exact.quantile(1.0).map_err(|e| e.to_string())?;
            let re = relative_error(est, tru);
            if re > 0.01 + 1e-9 {
                return Err(format!("max-quantile re {re}"));
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 4): the delta codec is bit-exact. For an arbitrary
/// baseline and an arbitrarily evolved current state (averaging with a
/// random partner, turnstile deletes, forced collapses), encoding the
/// delta, decoding it, and applying it to the baseline reconstructs the
/// current state bit for bit — entries, scalars, zero weight, collapse
/// depth, and therefore the fingerprint.
#[test]
fn prop_delta_roundtrip_bit_exact() {
    use duddsketch::rng::Xoshiro256pp;
    forall(
        "delta-roundtrip",
        SEED + 20,
        24,
        |r: &mut Xoshiro256pp| {
            let xs = gen::log_uniform_vec(r, 1200, 5.0, 3.0);
            let ys = gen::log_uniform_vec(r, 900, 4.0, 2.0);
            let id = r.index(64);
            let generation = r.index(1 << 16) as u64;
            let n_del = r.index(xs.len() / 4);
            let collapse = r.chance(0.3);
            (xs, ys, id, generation, n_del, collapse)
        },
        |(xs, ys, id, generation, n_del, collapse)| {
            let baseline =
                PeerState::init(*id, xs, 0.001, 128).map_err(|e| e.to_string())?;
            let fp = peer_state_fingerprint(&baseline);

            // Evolve a copy the way the protocol does: average with a
            // partner (fractional counters), delete some values
            // (turnstile), maybe collapse past the baseline's depth.
            let mut current = baseline.clone();
            let mut partner =
                PeerState::init(id + 1, ys, 0.001, 128).map_err(|e| e.to_string())?;
            PeerState::exchange(&mut current, &mut partner).map_err(|e| e.to_string())?;
            for &x in &xs[..*n_del] {
                current.sketch.delete(x);
            }
            if *collapse {
                current.sketch.force_collapse();
            }

            let delta = delta_payload(&baseline, fp, &current)
                .ok_or("delta_payload refused a same-lineage pair")?;
            if delta.baseline_fingerprint != fp {
                return Err("payload lost the fingerprint".into());
            }
            let frame = encode_exchange_delta_push(*generation, &delta);
            if frame.len() != delta_wire_size(&delta) {
                return Err(format!(
                    "wire-size accounting off: {} != {}",
                    frame.len(),
                    delta_wire_size(&delta)
                ));
            }
            let decoded = match decode_exchange(&frame).map_err(|e| e.to_string())? {
                ExchangeFrame::DeltaPush { generation: g, delta } if g == *generation => delta,
                other => return Err(format!("wrong frame decoded: {other:?}")),
            };
            let rebuilt = apply_delta(&baseline, &decoded).map_err(|e| e.to_string())?;
            if rebuilt.id != current.id
                || rebuilt.n_tilde.to_bits() != current.n_tilde.to_bits()
                || rebuilt.q_tilde.to_bits() != current.q_tilde.to_bits()
            {
                return Err("scalars differ after reconstruction".into());
            }
            if rebuilt.sketch.collapses() != current.sketch.collapses() {
                return Err("collapse depth differs".into());
            }
            if rebuilt.sketch.zero_weight().to_bits()
                != current.sketch.zero_weight().to_bits()
            {
                return Err("zero weight differs".into());
            }
            if rebuilt.sketch.positive_store().entries()
                != current.sketch.positive_store().entries()
                || rebuilt.sketch.negative_store().entries()
                    != current.sketch.negative_store().entries()
            {
                return Err("bucket entries differ".into());
            }
            if peer_state_fingerprint(&rebuilt) != peer_state_fingerprint(&current) {
                return Err("fingerprints differ".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Membership: MemberTable::merge is a pointwise max under a total order
// (incarnation, then status code, then smaller address string), so it must
// be a commutative, associative, idempotent lattice join — the property
// anti-entropy relies on for every node to end at the same table no matter
// the gossip order (ISSUE 7).
// ---------------------------------------------------------------------------

/// A small id/address pool so random entry streams actually contend on
/// the same ids (the interesting merge paths) instead of disjointly
/// unioning.
fn arb_member_entries(
    r: &mut duddsketch::rng::Xoshiro256pp,
    n: usize,
) -> Vec<duddsketch::service::MemberEntry> {
    use duddsketch::service::{MemberEntry, MemberStatus};
    (0..n)
        .map(|_| MemberEntry {
            id: r.index(6) as u64,
            addr: format!("127.0.0.1:{}", 7000 + r.index(4)).parse().unwrap(),
            incarnation: 1 + r.index(3) as u64,
            status: MemberStatus::from_code(r.index(3) as u8).unwrap(),
        })
        .collect()
}

/// Fold a stream of entries into a table via the same `upsert` the
/// production merge path uses.
fn member_table_of(
    entries: &[duddsketch::service::MemberEntry],
) -> duddsketch::service::MemberTable {
    let mut t = duddsketch::service::MemberTable::new();
    for e in entries {
        t.upsert(e.clone());
    }
    t
}

/// Invariant (ISSUE 7): merge is commutative — A ∪ B and B ∪ A are the
/// same table, even when the streams contend on ids at equal
/// incarnation and equal status (the address tie-break).
#[test]
fn prop_member_table_merge_commutative() {
    forall(
        "member-merge-commutative",
        SEED + 30,
        48,
        |r| (arb_member_entries(r, 12), arb_member_entries(r, 12)),
        |(xs, ys)| {
            let a = member_table_of(xs);
            let b = member_table_of(ys);
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            if ab != ba {
                return Err(format!("A∪B {ab:?} != B∪A {ba:?}"));
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 7): merge is associative — (A ∪ B) ∪ C equals
/// A ∪ (B ∪ C), so anti-entropy may aggregate tables along any tree.
#[test]
fn prop_member_table_merge_associative() {
    forall(
        "member-merge-associative",
        SEED + 31,
        48,
        |r| {
            (
                arb_member_entries(r, 10),
                arb_member_entries(r, 10),
                arb_member_entries(r, 10),
            )
        },
        |(xs, ys, zs)| {
            let (a, b, c) = (member_table_of(xs), member_table_of(ys), member_table_of(zs));
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            if left != right {
                return Err(format!("(A∪B)∪C {left:?} != A∪(B∪C) {right:?}"));
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 7): merge is idempotent — T ∪ T changes nothing and
/// reports nothing changed (a re-delivered table must not trigger a
/// protocol restart).
#[test]
fn prop_member_table_merge_idempotent() {
    forall(
        "member-merge-idempotent",
        SEED + 32,
        48,
        |r| arb_member_entries(r, 16),
        |xs| {
            let t = member_table_of(xs);
            let mut merged = t.clone();
            let out = merged.merge(&t);
            if merged != t {
                return Err(format!("self-merge changed the table: {merged:?} vs {t:?}"));
            }
            if out.changed || out.view_changed || out.joined + out.suspected + out.died != 0 {
                return Err(format!("self-merge reported changes: {out:?}"));
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 7): the table is a function of the entry *set* — a
/// randomly permuted and duplicated replay of the same stream folds to
/// the identical table (delivery order and re-delivery never matter).
#[test]
fn prop_member_table_merge_order_and_duplication_invariant() {
    forall(
        "member-merge-permutation",
        SEED + 33,
        48,
        |r| {
            let xs = arb_member_entries(r, 14);
            let mut replay = xs.clone();
            // Duplicate a random half of the stream, then shuffle.
            for _ in 0..xs.len() / 2 {
                let pick = replay[r.index(xs.len())].clone();
                replay.push(pick);
            }
            r.shuffle(&mut replay);
            (xs, replay)
        },
        |(xs, replay)| {
            let t1 = member_table_of(xs);
            let t2 = member_table_of(replay);
            if t1 != t2 {
                return Err(format!("replayed stream diverged: {t1:?} vs {t2:?}"));
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 9): reseeds ship as deltas, bit-exactly, *composed
/// across the reseed boundary*. A partner's cached baseline survives
/// the generation bump (baseline carry authenticates by fingerprint
/// alone), so the pre-reseed evolution and the reseed itself travel as
/// two successive `DeltaPush` frames against the rolling baseline — and
/// the receiver's reconstruction equals the reseeded state bit for bit.
/// The only allowed refusal is the protocol's legitimate full-frame
/// fallback: a fresh seed shallower than the baseline's collapse depth.
#[test]
fn prop_reseed_delta_bit_exact() {
    use duddsketch::rng::Xoshiro256pp;
    forall(
        "reseed-delta",
        SEED + 22,
        24,
        |r: &mut Xoshiro256pp| {
            let xs = gen::uniform_vec(r, 900, 1.0, 1e3);
            let ys = gen::uniform_vec(r, 600, 1.0, 1e3);
            let zs = gen::uniform_vec(r, 700, 1.0, 1e3);
            let id = r.index(64);
            let generation = 1 + r.index(1 << 16) as u64;
            let collapse = r.chance(0.3);
            let distinguished = r.chance(0.5);
            (xs, ys, zs, id, generation, collapse, distinguished)
        },
        |(xs, ys, zs, id, generation, collapse, distinguished)| {
            // Leg 1 — ordinary pre-reseed evolution: the partner cached
            // this node's state and receives the averaged update as a
            // delta, advancing its rolling baseline.
            let cached = PeerState::init(*id, xs, 0.01, 1024).map_err(|e| e.to_string())?;
            let fp = peer_state_fingerprint(&cached);
            let mut current = cached.clone();
            let mut partner =
                PeerState::init(id + 1, ys, 0.01, 1024).map_err(|e| e.to_string())?;
            PeerState::exchange(&mut current, &mut partner).map_err(|e| e.to_string())?;
            let d1 = delta_payload(&cached, fp, &current).ok_or("leg-1 delta refused")?;
            let carried = apply_delta(&cached, &d1).map_err(|e| e.to_string())?;
            if peer_state_fingerprint(&carried) != peer_state_fingerprint(&current) {
                return Err("leg-1 reconstruction diverged".into());
            }

            // The reseed (epoch fallback or death re-anchor): the local
            // state is *replaced*, not evolved — same α₀ lineage, fresh
            // counters, q̃ re-anchored by the distinguished rule.
            let mut reseeded =
                PeerState::init(*id, zs, 0.01, 1024).map_err(|e| e.to_string())?;
            reseeded.q_tilde = if *distinguished { 1.0 } else { 0.0 };
            if *collapse {
                reseeded.sketch.force_collapse();
            }

            // Leg 2 — the reseed ships against the *carried* (post-leg-1)
            // baseline even though the generation bumped in between.
            let fp2 = peer_state_fingerprint(&carried);
            let Some(d2) = delta_payload(&carried, fp2, &reseeded) else {
                return if reseeded.sketch.collapses() < carried.sketch.collapses() {
                    Ok(()) // legitimate full-frame fallback
                } else {
                    Err("leg-2 delta refused without cause".into())
                };
            };
            let frame = encode_exchange_delta_push(*generation, &d2);
            let decoded = match decode_exchange(&frame).map_err(|e| e.to_string())? {
                ExchangeFrame::DeltaPush { generation: g, delta } if g == *generation => delta,
                other => return Err(format!("wrong frame decoded: {other:?}")),
            };
            let rebuilt = apply_delta(&carried, &decoded).map_err(|e| e.to_string())?;
            if rebuilt.n_tilde.to_bits() != reseeded.n_tilde.to_bits()
                || rebuilt.q_tilde.to_bits() != reseeded.q_tilde.to_bits()
                || rebuilt.sketch.collapses() != reseeded.sketch.collapses()
                || rebuilt.sketch.zero_weight().to_bits()
                    != reseeded.sketch.zero_weight().to_bits()
                || rebuilt.sketch.positive_store().entries()
                    != reseeded.sketch.positive_store().entries()
                || rebuilt.sketch.negative_store().entries()
                    != reseeded.sketch.negative_store().entries()
            {
                return Err("reseed delta reconstruction not bit-exact".into());
            }
            if peer_state_fingerprint(&rebuilt) != peer_state_fingerprint(&reseeded) {
                return Err("fingerprints differ after the reseed".into());
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 9): the q̃ mass sums to *exactly* 1.0 per generation
/// under arbitrary interleavings of restart-free joins (q̃ = 0
/// admission), push–pull exchanges (halving is exact in f64 for dyadic
/// masses), epoch carries (q̃ untouched by construction), and death
/// re-anchors (reseed: the distinguished peer takes 1, everyone else 0).
/// This is the fixed point that makes join-without-restart sound
/// (`docs/PROTOCOL.md` §10) — the comparison is on bits, not within an
/// epsilon.
#[test]
fn prop_q_mass_exactly_one_under_churn() {
    use duddsketch::rng::Xoshiro256pp;
    forall(
        "q-mass-churn",
        SEED + 23,
        32,
        |r: &mut Xoshiro256pp| {
            (0..40)
                .map(|_| (r.index(8) as u8, r.index(1 << 16), r.index(1 << 16)))
                .collect::<Vec<(u8, usize, usize)>>()
        },
        |ops| {
            let dataset =
                |id: usize| -> Vec<f64> { (0..20).map(|i| 1.0 + (id * 20 + i) as f64).collect() };
            let spawn = |id: usize, q: f64| -> Result<PeerState, String> {
                let mut p =
                    PeerState::init(id, &dataset(id), 0.01, 1024).map_err(|e| e.to_string())?;
                p.q_tilde = q;
                Ok(p)
            };
            // A freshly re-anchored 3-peer fleet: slot 0 is distinguished.
            let mut peers = vec![spawn(0, 1.0)?, spawn(1, 0.0)?, spawn(2, 0.0)?];
            let mut next_id = 3usize;
            for (i, (op, pa, pb)) in ops.iter().enumerate() {
                match op {
                    // Join without restart: q̃ = 0 admission is
                    // mass-conserving by construction.
                    0 => {
                        peers.push(spawn(next_id, 0.0)?);
                        next_id += 1;
                    }
                    // Death re-anchors (and ONLY deaths): drop a peer,
                    // reseed every survivor, distinguished takes 1.
                    1 => {
                        if peers.len() > 2 {
                            peers.remove(pa % peers.len());
                            for (k, p) in peers.iter_mut().enumerate() {
                                let id = p.id;
                                *p = spawn(id, if k == 0 { 1.0 } else { 0.0 })?;
                            }
                        }
                    }
                    // Epoch carry: fold an additive ingest delta into
                    // the averaged slot in place — q̃ untouched.
                    6 | 7 => {
                        let mut delta: UddSketch = UddSketch::new(0.01, 1024).unwrap();
                        delta.extend(&dataset(1000 + i));
                        peers[pa % peers.len()]
                            .carry_epoch_delta(&delta)
                            .map_err(|e| e.to_string())?;
                    }
                    // Push–pull exchange between two distinct peers.
                    _ => {
                        let n = peers.len();
                        let a = pa % n;
                        let mut b = pb % (n - 1);
                        if b >= a {
                            b += 1;
                        }
                        let (lo, hi) = (a.min(b), a.max(b));
                        let (left, right) = peers.split_at_mut(hi);
                        PeerState::exchange(&mut left[lo], &mut right[0])
                            .map_err(|e| e.to_string())?;
                    }
                }
                let sum: f64 = peers.iter().map(|p| p.q_tilde).sum();
                if sum.to_bits() != 1.0f64.to_bits() {
                    return Err(format!(
                        "after op {i} ({op}): Σq̃ = {sum:?} is not exactly 1 \
                         over {} peers",
                        peers.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// A short string over a hostile alphabet — quotes, backslashes,
/// newlines, a C0 control, multi-byte UTF-8, and JSON/Prometheus
/// structural characters — for the encoder/escaping properties below.
fn nasty_string(r: &mut duddsketch::rng::Xoshiro256pp) -> String {
    const POOL: [char; 14] = [
        'a', 'Z', '7', '"', '\\', '\n', '\t', '\u{1}', 'µ', ':', '=', ',', '{', '}',
    ];
    (0..r.index(12)).map(|_| POOL[r.index(POOL.len())]).collect()
}

/// Invariant (ISSUE 10): the hand-rolled JSONL event encoder
/// round-trips through the crate's own flat-JSON parser for arbitrary
/// field values — including node/peer strings full of quotes,
/// backslashes, newlines, and control characters — and every encoded
/// event is exactly one line. `dudd-observe` joins event logs through
/// exactly this parser, so encoder/parser drift would silently break
/// causal joins.
#[test]
#[allow(clippy::field_reassign_with_default)]
fn prop_event_log_encoder_roundtrips() {
    use duddsketch::obs::{
        encode_exchange_event, encode_membership_event, encode_round_event, parse_flat_json,
        ExchangeSpan, RoundPhase, RoundTrace,
    };
    use duddsketch::rng::Xoshiro256pp;
    use std::time::Duration;

    const KINDS: [&str; 4] = ["full", "delta", "local", "unknown"];
    const OUTCOMES: [&str; 4] = ["ok", "reject:busy", "reject:stale_generation", "error:io"];
    const CAUSES: [Option<&'static str>; 4] = [
        None,
        Some("epoch_advance"),
        Some("view_change"),
        Some("generation_catch_up"),
    ];

    forall(
        "event-log-roundtrip",
        SEED + 40,
        64,
        |r: &mut Xoshiro256pp| {
            let node = nasty_string(r);
            let peer = nasty_string(r);
            let nums: Vec<u64> = (0..12).map(|_| r.index(1 << 30) as u64).collect();
            let picks = (r.index(KINDS.len()), r.index(OUTCOMES.len()), r.index(CAUSES.len()));
            let trace_id = 1 + ((nums[0] << 33) | (nums[1] << 2));
            (node, peer, nums, picks, trace_id, r.chance(0.5))
        },
        |(node, peer, nums, (ki, oi, ci), trace_id, reseeded)| {
            let expect_str = |m: &std::collections::BTreeMap<String, duddsketch::obs::JsonValue>,
                              key: &str,
                              want: &str|
             -> Result<(), String> {
                match m.get(key).and_then(|v| v.as_str()) {
                    Some(got) if got == want => Ok(()),
                    other => Err(format!("{key}: {other:?} != {want:?}")),
                }
            };
            let expect_num = |m: &std::collections::BTreeMap<String, duddsketch::obs::JsonValue>,
                              key: &str,
                              want: u64|
             -> Result<(), String> {
                match m.get(key).and_then(|v| v.as_u64()) {
                    Some(got) if got == want => Ok(()),
                    other => Err(format!("{key}: {other:?} != {want}")),
                }
            };

            // -- exchange event: the causal-join record --------------------
            let span = ExchangeSpan {
                trace_id: *trace_id,
                initiator: *reseeded,
                peer: peer.clone(),
                generation: nums[2],
                kind: KINDS[*ki],
                bytes: nums[3] as usize,
                outcome: OUTCOMES[*oi],
                connect: Duration::from_micros(nums[4]),
                push: Duration::from_micros(nums[5]),
                reply: Duration::from_micros(nums[6]),
                commit: Duration::from_micros(nums[7]),
            };
            let line = encode_exchange_event(node, nums[8], nums[9], &span);
            if line.contains('\n') {
                return Err(format!("exchange event is not one line: {line:?}"));
            }
            let m = parse_flat_json(&line).ok_or_else(|| format!("unparseable: {line:?}"))?;
            expect_str(&m, "event", "exchange")?;
            expect_str(&m, "node", node)?;
            expect_str(&m, "peer", peer)?;
            expect_str(&m, "trace_id", &trace_id.to_string())?;
            expect_str(&m, "role", if *reseeded { "initiator" } else { "server" })?;
            expect_str(&m, "kind", KINDS[*ki])?;
            expect_str(&m, "outcome", OUTCOMES[*oi])?;
            expect_num(&m, "t_ms", nums[8])?;
            expect_num(&m, "round", nums[9])?;
            expect_num(&m, "generation", nums[2])?;
            expect_num(&m, "bytes", nums[3])?;
            expect_num(&m, "connect_us", nums[4])?;
            expect_num(&m, "push_us", nums[5])?;
            expect_num(&m, "reply_us", nums[6])?;
            expect_num(&m, "commit_us", nums[7])?;
            if trace_id.to_string().parse::<u64>() != Ok(*trace_id) {
                return Err("trace id does not survive the decimal string".into());
            }

            // -- round event -----------------------------------------------
            let mut trace = RoundTrace::default();
            trace.round = nums[9];
            trace.generation = nums[2];
            trace.reseeded = *reseeded;
            trace.restart_cause = CAUSES[*ci];
            trace.exchanges = nums[10] as usize;
            trace.failed = nums[11] as usize;
            trace.bytes = nums[3] as usize;
            trace.total = Duration::from_micros(nums[4]);
            let trace = trace
                .with_phase(RoundPhase::Refresh, Duration::from_micros(nums[5]))
                .with_phase(RoundPhase::Exchange, Duration::from_micros(nums[6]));
            let line = encode_round_event(node, nums[8], &trace);
            if line.contains('\n') {
                return Err(format!("round event is not one line: {line:?}"));
            }
            let m = parse_flat_json(&line).ok_or_else(|| format!("unparseable: {line:?}"))?;
            expect_str(&m, "event", "round")?;
            expect_str(&m, "node", node)?;
            match (CAUSES[*ci], m.get("restart_cause")) {
                (Some(c), Some(v)) if v.as_str() == Some(c) => {}
                (None, Some(duddsketch::obs::JsonValue::Null)) => {}
                (want, got) => return Err(format!("restart_cause: {got:?} != {want:?}")),
            }
            expect_num(&m, "round", nums[9])?;
            expect_num(&m, "generation", nums[2])?;
            expect_num(&m, "exchanges", nums[10])?;
            expect_num(&m, "failed", nums[11])?;
            expect_num(&m, "bytes", nums[3])?;
            expect_num(&m, "total_us", nums[4])?;
            expect_num(&m, "refresh_us", nums[5])?;
            expect_num(&m, "exchange_us", nums[6])?;
            expect_num(&m, "membership_us", 0)?;
            match m.get("reseeded") {
                Some(duddsketch::obs::JsonValue::Bool(b)) if b == reseeded => {}
                other => return Err(format!("reseeded: {other:?} != {reseeded}")),
            }

            // -- membership event ------------------------------------------
            let line = encode_membership_event(node, nums[8], nums[9], nums[10], nums[11], nums[2]);
            if line.contains('\n') {
                return Err(format!("membership event is not one line: {line:?}"));
            }
            let m = parse_flat_json(&line).ok_or_else(|| format!("unparseable: {line:?}"))?;
            expect_str(&m, "event", "membership")?;
            expect_str(&m, "node", node)?;
            expect_num(&m, "joined", nums[10])?;
            expect_num(&m, "suspected", nums[11])?;
            expect_num(&m, "died", nums[2])?;
            Ok(())
        },
    );
}

/// Invariant (ISSUE 10): Prometheus label values render escaped per the
/// text-exposition spec — backslash → `\\`, double quote → `\"`,
/// newline → `\n` — so a hostile value never splits a sample line or
/// unbalances its quotes, and the spec unescape recovers the original
/// value exactly.
#[test]
fn prop_prometheus_label_values_escape_per_spec() {
    use duddsketch::obs::MetricsRegistry;

    forall(
        "label-escape",
        SEED + 41,
        64,
        nasty_string,
        |value| {
            let reg = MetricsRegistry::new();
            let c = reg
                .counter_with(
                    "t_escape_total",
                    "label escape fixture",
                    &[("path", value.as_str())],
                )
                .map_err(|e| e.to_string())?;
            c.inc();
            let text = reg.render();

            // However hostile the value, the family renders exactly one
            // sample line (newlines must not split it).
            let samples: Vec<&str> = text
                .lines()
                .filter(|l| l.starts_with("t_escape_total"))
                .collect();
            if samples.len() != 1 {
                return Err(format!("expected 1 sample line, got {samples:?}"));
            }
            let inner = samples[0]
                .strip_prefix("t_escape_total{path=\"")
                .ok_or_else(|| format!("malformed sample line: {:?}", samples[0]))?;
            let end = inner
                .rfind("\"} ")
                .ok_or_else(|| format!("unterminated label value: {inner:?}"))?;
            let escaped = &inner[..end];

            // Spec unescape: \\, \", \n are the only escapes; a raw
            // quote or newline inside the value is a rendering bug.
            let mut un = String::new();
            let mut it = escaped.chars();
            while let Some(ch) = it.next() {
                if ch == '\\' {
                    match it.next() {
                        Some('\\') => un.push('\\'),
                        Some('"') => un.push('"'),
                        Some('n') => un.push('\n'),
                        other => return Err(format!("stray escape \\{other:?} in {escaped:?}")),
                    }
                } else {
                    if ch == '"' || ch == '\n' {
                        return Err(format!("unescaped {ch:?} in {escaped:?}"));
                    }
                    un.push(ch);
                }
            }
            if un != **value {
                return Err(format!("unescape mismatch: {un:?} != {value:?}"));
            }
            Ok(())
        },
    );
}

/// Invariant (ISSUE 4): no corrupted or stale-baseline delta frame slips
/// through. Truncation at any offset fails to decode (so the transport
/// cancels the exchange, §7.2), and a frame whose baseline fingerprint
/// was tampered with decodes to a fingerprint that no longer matches the
/// receiver's cache — exactly the condition that draws the
/// `BaselineMismatch` reject and the automatic full-frame fallback,
/// leaving both sides at their pre-round state.
#[test]
fn prop_delta_frame_corruption_detected() {
    use duddsketch::rng::Xoshiro256pp;
    forall(
        "delta-corruption",
        SEED + 21,
        16,
        |r: &mut Xoshiro256pp| {
            let xs = gen::uniform_vec(r, 500, 1.0, 1e4);
            let ys = gen::uniform_vec(r, 300, 1.0, 1e3);
            let cut_unit = r.next_f64();
            let flip = r.index(8);
            (xs, ys, cut_unit, flip)
        },
        |(xs, ys, cut_unit, flip)| {
            let baseline = PeerState::init(2, xs, 0.01, 64).map_err(|e| e.to_string())?;
            let fp = peer_state_fingerprint(&baseline);
            let mut current = baseline.clone();
            let mut partner = PeerState::init(5, ys, 0.01, 64).map_err(|e| e.to_string())?;
            PeerState::exchange(&mut current, &mut partner).map_err(|e| e.to_string())?;
            let delta = delta_payload(&baseline, fp, &current)
                .ok_or("delta_payload refused a same-lineage pair")?;
            let buf = encode_exchange_delta_push(7, &delta);

            // Truncation at a random offset and the structural edges.
            let random_cut = ((buf.len() - 1) as f64 * cut_unit) as usize;
            for cut in [0usize, 4, 5, 6, 13, 21, random_cut, buf.len() - 1] {
                if decode_exchange(&buf[..cut]).is_ok() {
                    return Err(format!("truncation at {cut} decoded"));
                }
            }
            // Tampered fingerprint (bytes 14..22 of the frame): decodes,
            // but no longer names the receiver's baseline.
            let mut bad = buf.clone();
            bad[14 + flip] ^= 0xFF;
            match decode_exchange(&bad).map_err(|e| e.to_string())? {
                ExchangeFrame::DeltaPush { delta: d, .. } => {
                    if d.baseline_fingerprint == fp {
                        return Err("tampered fingerprint still matched".into());
                    }
                }
                other => return Err(format!("wrong frame decoded: {other:?}")),
            }
            Ok(())
        },
    );
}
