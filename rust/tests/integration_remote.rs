//! Integration: the transport-trait redesign (ISSUE 3) and the hot-path
//! overhaul (ISSUE 4: connection reuse, per-member locking, delta
//! exchanges).
//!
//! Acceptance:
//! * a fleet of ≥ 4 real nodes gossiping over **loopback TCP** — one
//!   poll-driven serve loop per node, length-prefixed codec frames,
//!   per-exchange deadlines, **connection pooling and delta frames
//!   enabled** — converges to the sequential union-stream sketch within
//!   α while ingest continues;
//! * the refactored `InProcess` transport reproduces PR 2's `GlobalView`
//!   results **exactly** (old-vs-new parity against the simulation
//!   engine's `fan_out_round`, driven with the loop's own rng
//!   discipline);
//! * cancelled exchanges (timeouts, malformed frames) leave both sides'
//!   q̃ mass and averaged state bit-for-bit at their pre-round values
//!   (§7.2);
//! * a pooled connection gone stale recovers via a fresh-connect retry
//!   **without** counting a failed exchange (ISSUE 4 bugfix), and a
//!   stale delta baseline downgrades to full frames on the same
//!   connection, leaving the server's state untouched.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

mod common;

use duddsketch::config::{GossipLoopConfig, ServiceConfig};
use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::gossip::{fan_out_round, PeerState};
use duddsketch::metrics::relative_error;
use duddsketch::prelude::*;
use duddsketch::rng::default_rng;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const ACCEPT_QS: [f64; 3] = [0.5, 0.9, 0.99];

fn service_cfg() -> ServiceConfig {
    let mut c = ServiceConfig::default();
    c.shards = 2;
    c.batch_size = 256;
    c.gossip.round_interval_ms = 0; // tests are the clock
    c
}

/// Bind `n` transports first (address book before any loop starts), then
/// build the fleet: node k's own service at global member index k,
/// everyone else a remote peer. Pooling and delta exchanges follow the
/// config's gossip knobs (both on by default). The transports are
/// returned alongside the nodes so tests can read pool statistics.
fn tcp_fleet(n: usize, cfg: &ServiceConfig) -> (Vec<Node>, Vec<Arc<TcpTransport>>) {
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    let transports: Vec<Arc<TcpTransport>> = (0..n)
        .map(|_| Arc::new(TcpTransport::bind_with("127.0.0.1:0", opts.clone()).unwrap()))
        .collect();
    let addrs: Vec<SocketAddr> = transports
        .iter()
        .map(|t| t.listen_addr().unwrap())
        .collect();
    let nodes = transports
        .iter()
        .enumerate()
        .map(|(k, t)| {
            let mut b = Node::builder()
                .config(cfg.clone())
                .self_index(k)
                .transport_shared(t.clone());
            for (j, &addr) in addrs.iter().enumerate() {
                if j != k {
                    b = b.remote_peer(addr);
                }
            }
            b.build().unwrap()
        })
        .collect();
    (nodes, transports)
}

/// Sweep all nodes until every node's view is converged on the expected
/// union total (bounded); returns the sweeps it took.
fn sweep_to_convergence(fleet: &[Node], total: f64, max_sweeps: usize) -> usize {
    for sweep in 1..=max_sweeps {
        for node in fleet {
            node.step();
        }
        let views: Vec<_> = fleet
            .iter()
            .map(|n| n.global_view().expect("gossip enabled"))
            .collect();
        let gen0 = views[0].generation();
        let all = views.iter().all(|v| {
            v.generation() == gen0 && v.converged() && v.estimated_total() == total
        });
        if all {
            return sweep;
        }
    }
    let states: Vec<String> = fleet
        .iter()
        .map(|n| {
            let v = n.global_view().unwrap();
            format!(
                "gen={} total={} converged={}",
                v.generation(),
                v.estimated_total(),
                v.converged()
            )
        })
        .collect();
    panic!("TCP fleet did not converge within {max_sweeps} sweeps: {states:?}");
}

/// The acceptance test: four real nodes on loopback TCP, ingest landing
/// in chunks between sweeps (each node absorbs its own epoch advances
/// with the restart-free carry — no generation ever bumps), every
/// node's converged view within α of the sequential union sketch.
#[test]
fn four_tcp_nodes_converge_to_union_while_ingesting() {
    let nodes = 4;
    let items = 3_000;
    let master = default_rng(42);
    let datasets: Vec<Vec<f64>> = (0..nodes)
        .map(|i| peer_dataset(DatasetKind::Exponential, i, items, &master))
        .collect();

    let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    for d in &datasets {
        seq.extend(d);
    }

    let cfg = service_cfg();
    assert!(cfg.gossip.delta_exchanges, "delta frames on by default");
    assert!(cfg.gossip.pool_connections > 0, "pooling on by default");
    let (fleet, transports) = tcp_fleet(nodes, &cfg);
    for (k, node) in fleet.iter().enumerate() {
        assert!(
            node.listen_addr().is_some(),
            "node {k} must run a serve loop"
        );
        assert_eq!(node.self_member(), k);
        assert_eq!(node.gossip().unwrap().members(), nodes);
    }

    // Live ingest: every node consumes its stream in 3 chunks with gossip
    // sweeps interleaved — under restart-free churn each node folds its
    // own epoch advances into its averaged slot in place, so the fleet
    // never leaves generation 1.
    let mut writers: Vec<_> = fleet.iter().map(|n| n.writer()).collect();
    for step in 0..3 {
        for (k, node) in fleet.iter().enumerate() {
            writers[k].insert_batch(&datasets[k][step * 1_000..(step + 1) * 1_000]);
            writers[k].flush();
            node.flush();
        }
        for node in &fleet {
            node.step();
        }
    }
    drop(writers);

    let sweeps = sweep_to_convergence(&fleet, (nodes * items) as f64, 400);

    let generations: Vec<u64> = fleet
        .iter()
        .map(|n| n.global_view().unwrap().generation())
        .collect();
    assert!(
        generations.iter().all(|&g| g == generations[0]),
        "every node must settle on one restart generation: {generations:?}"
    );
    assert_eq!(
        generations[0], 1,
        "restart-free: insert-only ingest must never bump the generation"
    );

    for (k, node) in fleet.iter().enumerate() {
        let v = node.global_view().unwrap();
        assert_eq!(v.estimated_peers(), nodes as f64, "node {k} fleet size");
        assert_eq!(
            v.estimated_total(),
            (nodes * items) as f64,
            "node {k} union length"
        );
        for q in ACCEPT_QS {
            let est = v.query(q).unwrap();
            let truth = seq.quantile(q).unwrap();
            let re = relative_error(est, truth);
            assert!(
                re <= seq.alpha() + 1e-9,
                "node {k} q={q} after {sweeps} sweeps: view {est} vs \
                 sequential {truth} (re {re} > alpha {})",
                seq.alpha()
            );
        }
    }
    // The hot-path machinery actually engaged across the run.
    let reused: usize = transports.iter().map(|t| t.pool_stats().reused).sum();
    assert!(reused > 0, "no exchange ever reused a pooled connection");
    for node in fleet {
        node.shutdown();
    }
}

/// Old-vs-new parity: the refactored loop on the `InProcess` transport
/// must reproduce PR 2's results **bit for bit**. The reference is the
/// simulation engine's `fan_out_round` — the exact code PR 2's loop
/// called — driven with the loop's own rng derivation discipline; every
/// round must agree on exchange counts, wire bytes, and every member's
/// full averaged state.
#[test]
fn in_process_transport_reproduces_pr2_results_exactly() {
    let n = 5;
    let items = 800;
    let cfg = GossipLoopConfig::default();
    let master = default_rng(cfg.seed);
    let datasets: Vec<Vec<f64>> = (0..n)
        .map(|i| peer_dataset(DatasetKind::Uniform, i, items, &master))
        .collect();

    let members: Vec<GossipMember> = datasets
        .iter()
        .map(|d| GossipMember::from_dataset(d, 0.001, 1024).unwrap())
        .collect();
    let gl = GossipLoop::start(cfg.clone(), members).unwrap();

    // PR 2 reference: same member states, same graph derivation
    // (master.derive(0x6EA4)), same round rng (master.derive(0x1005)),
    // same engine round.
    let mut grng = master.derive(0x6EA4);
    let graph = duddsketch::graph::from_kind(cfg.graph, n, &mut grng);
    let mut rng = master.derive(0x1005);
    let mut states: Vec<PeerState> = datasets
        .iter()
        .enumerate()
        .map(|(i, d)| {
            let mut s: UddSketch = UddSketch::new(0.001, 1024).unwrap();
            s.extend(d);
            PeerState::from_sketch(i, &s)
        })
        .collect();
    let online = vec![true; n];

    for round in 1..=8 {
        let (exchanges, dropped, bytes) =
            fan_out_round(&mut states, &graph, &online, cfg.fan_out, 0.0, &mut rng);
        assert_eq!(dropped, 0);
        let r = gl.step();
        assert_eq!(r.exchanges, exchanges, "round {round} exchange count");
        assert_eq!(r.bytes, bytes, "round {round} wire bytes");
        assert_eq!(r.failed, 0, "round {round} failures");
        for i in 0..n {
            let v = gl.member_view(i);
            let s = &states[i];
            assert_eq!(
                v.state().n_tilde.to_bits(),
                s.n_tilde.to_bits(),
                "round {round} member {i} n_tilde"
            );
            assert_eq!(
                v.state().q_tilde.to_bits(),
                s.q_tilde.to_bits(),
                "round {round} member {i} q_tilde"
            );
            assert_eq!(
                v.state().sketch.positive_store().entries(),
                s.sketch.positive_store().entries(),
                "round {round} member {i} buckets"
            );
            for q in ACCEPT_QS {
                assert_eq!(
                    v.query(q).unwrap().to_bits(),
                    s.query(q).unwrap().to_bits(),
                    "round {round} member {i} q={q}"
                );
            }
        }
    }
    gl.shutdown();
}

/// §7.2 on the wire, initiator side: a partner that accepts the push but
/// never replies burns the deadline; the exchange must be counted failed
/// and leave the initiator's state bit-for-bit untouched.
#[test]
fn timed_out_tcp_exchange_keeps_initiator_pre_round_state() {
    // Black-hole partner: accepts, reads nothing, never replies. The
    // sockets are held open until the test signals it is done asserting
    // — a fixed sleep here would race the assertions on a slow machine.
    let sink = TcpListener::bind("127.0.0.1:0").unwrap();
    let sink_addr = sink.local_addr().unwrap();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let sink_thread = std::thread::spawn(move || {
        let mut held = Vec::new();
        for _ in 0..2 {
            if let Ok((stream, _)) = sink.accept() {
                held.push(stream); // keep the socket open, say nothing
            }
        }
        let _ = done_rx.recv_timeout(Duration::from_secs(30));
        drop(held);
    });

    let mut cfg = service_cfg();
    cfg.gossip.exchange_deadline_ms = 120;
    let node = Node::builder()
        .config(cfg)
        .self_index(0)
        .transport(TcpTransport::connect_only(Duration::from_millis(120)).unwrap())
        .remote_peer(sink_addr)
        .build()
        .unwrap();
    let mut w = node.writer();
    w.insert_batch(&(1..=500).map(f64::from).collect::<Vec<_>>());
    w.flush();
    node.flush();

    // First step absorbs epoch 1 (restart-free carry) and then fails
    // its one exchange.
    let r1 = node.step().unwrap();
    assert!(r1.epoch_carried);
    assert!(!r1.reseeded);
    assert_eq!(r1.exchanges, 0);
    assert_eq!(r1.failed, 1, "timed-out exchange must be counted");
    let before = node.global_view().unwrap().state().clone();

    let r2 = node.step().unwrap();
    assert_eq!(r2.exchanges, 0);
    assert_eq!(r2.failed, 1);
    let after = node.global_view().unwrap().state().clone();
    assert_eq!(after.n_tilde.to_bits(), before.n_tilde.to_bits());
    assert_eq!(after.q_tilde.to_bits(), before.q_tilde.to_bits());
    assert_eq!(
        after.sketch.positive_store().entries(),
        before.sketch.positive_store().entries(),
        "cancelled exchange must not move any bucket mass"
    );
    assert_eq!(after.sketch.count().to_bits(), before.sketch.count().to_bits());

    drop(w);
    node.shutdown();
    let _ = done_tx.send(());
    sink_thread.join().unwrap();
}

/// §7.2 on the wire, serve side: malformed, truncated, and wrong-version
/// frames are rejected by the accept loop without touching the node's
/// state, and a well-formed push still works afterwards.
#[test]
fn malformed_frames_leave_server_state_unchanged() {
    let mut cfg = service_cfg();
    cfg.gossip.exchange_deadline_ms = 300;
    // The remote peer list needs an entry; point it at a port nobody
    // answers so this node's own exchanges simply fail.
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let node = Node::builder()
        .config(cfg)
        .self_index(0)
        .transport(TcpTransport::bind("127.0.0.1:0", Duration::from_millis(300)).unwrap())
        .remote_peer(dead_addr)
        .build()
        .unwrap();
    let addr = node.listen_addr().expect("accept loop bound");
    let mut w = node.writer();
    w.insert_batch(&(1..=400).map(f64::from).collect::<Vec<_>>());
    w.flush();
    node.flush();
    node.step(); // seed epoch 1 into the protocol state
    let before = node.global_view().unwrap().state().clone();

    let talk = |payload: &[u8], truncate_to: Option<usize>| -> Vec<u8> {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        s.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
        match truncate_to {
            Some(k) => {
                s.write_all(&payload[..k]).unwrap();
                drop(s.shutdown(std::net::Shutdown::Write));
            }
            None => s.write_all(payload).unwrap(),
        }
        let mut reply = Vec::new();
        let _ = s.read_to_end(&mut reply);
        reply
    };

    // Garbage bytes → Malformed reject.
    let reply = talk(b"this is not an exchange frame at all....", None);
    assert!(!reply.is_empty(), "server should answer garbage with a reject");

    // A valid push whose version byte is flipped → Malformed reject.
    let alien = PeerState::init(9, &[1.0, 2.0, 3.0], 0.001, 1024).unwrap();
    let mut frame = duddsketch::sketch::encode_exchange_push(u64::MAX, &alien);
    frame[4] = 99;
    let reply = talk(&frame, None);
    assert!(!reply.is_empty(), "wrong version should be rejected, not served");

    // A truncated push → connection dies server-side, no state change.
    let frame = duddsketch::sketch::encode_exchange_push(u64::MAX, &alien);
    let _ = talk(&frame, Some(frame.len() / 2));

    let after = node.global_view().unwrap().state().clone();
    assert_eq!(after.n_tilde.to_bits(), before.n_tilde.to_bits());
    assert_eq!(after.q_tilde.to_bits(), before.q_tilde.to_bits());
    assert_eq!(
        after.sketch.positive_store().entries(),
        before.sketch.positive_store().entries(),
        "bad frames must never touch the serve state"
    );

    // A genuine push at the node's generation still works: the reply is
    // the averaged state and the server adopts it.
    let gen = node.global_view().unwrap().generation();
    let peer = PeerState::init(1, &[1_000.0; 100], 0.001, 1024).unwrap();
    let frame = duddsketch::sketch::encode_exchange_push(gen, &peer);
    let reply_bytes = talk(&frame, None);
    assert!(reply_bytes.len() > 4, "expected a framed reply");
    let reply = duddsketch::sketch::decode_exchange(&reply_bytes[4..]).unwrap();
    match reply {
        duddsketch::sketch::ExchangeFrame::Reply { generation, state } => {
            assert_eq!(generation, gen);
            assert_eq!(state.id, 1, "reply carries the initiator's id");
            let served = node.global_view().unwrap().state().clone();
            assert_eq!(served.n_tilde.to_bits(), state.n_tilde.to_bits());
            assert_eq!(served.q_tilde.to_bits(), state.q_tilde.to_bits());
        }
        other => panic!("expected a reply frame, got {other:?}"),
    }

    drop(w);
    node.shutdown();
}

/// Two real nodes, one with an accept loop and one client-only: the
/// initiator's push lands on the server's state through the wire, both
/// sides adopt the same averaged state, and the restart generations
/// sync end to end. One exchange fully averages a 2-node fleet, so the
/// estimates are exact.
#[test]
fn two_tcp_nodes_sync_generations_and_average_exactly() {
    let mut cfg = service_cfg();
    cfg.gossip.exchange_deadline_ms = 2_000;
    // Node A serves an accept loop; its own remote peer entry (node B)
    // is client-only, so A's outbound exchanges simply fail — all mixing
    // flows through B's pushes.
    let b_placeholder = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let a = Node::builder()
        .config(cfg.clone())
        .self_index(0)
        .transport(TcpTransport::bind("127.0.0.1:0", Duration::from_millis(2_000)).unwrap())
        .remote_peer(b_placeholder)
        .build()
        .unwrap();
    let a_addr = a.listen_addr().unwrap();
    let mut wa = a.writer();
    wa.insert_batch(&(1..=200).map(f64::from).collect::<Vec<_>>());
    wa.flush();
    a.flush();
    a.step(); // absorbs epoch 1 via the restart-free carry (generation stays 1)

    // Node B agrees on the member order: A is member 0, B is member 1.
    let b = Node::builder()
        .config(cfg)
        .self_index(1)
        .transport(TcpTransport::connect_only(Duration::from_millis(2_000)).unwrap())
        .remote_peer(a_addr)
        .build()
        .unwrap();
    let mut wb = b.writer();
    wb.insert_batch(&(201..=400).map(f64::from).collect::<Vec<_>>());
    wb.flush();
    b.flush();

    let mut completed = 0usize;
    for _ in 0..8 {
        let r = b.step().unwrap();
        completed += r.exchanges;
        if completed > 0 {
            break;
        }
    }
    assert!(completed > 0, "B never completed an exchange with A");

    let va = a.global_view().unwrap();
    let vb = b.global_view().unwrap();
    assert_eq!(va.generation(), vb.generation(), "generations synced over TCP");
    assert_eq!(vb.estimated_peers(), 2.0);
    assert_eq!(vb.estimated_total(), 400.0);
    // Both sides hold the same averaged state (A committed exactly what
    // it replied; B adopted exactly that reply).
    assert_eq!(va.state().q_tilde + vb.state().q_tilde, 1.0);
    assert_eq!(
        va.state().n_tilde.to_bits(),
        vb.state().n_tilde.to_bits()
    );
    let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    seq.extend(&(1..=400).map(f64::from).collect::<Vec<_>>());
    for q in ACCEPT_QS {
        assert_eq!(
            vb.query(q).unwrap(),
            seq.quantile(q).unwrap(),
            "2-node fleet averages exactly, q={q}"
        );
    }

    drop(wa);
    drop(wb);
    a.shutdown();
    b.shutdown();
}

/// ISSUE 4 bugfix regression: a pooled connection whose server went away
/// must recover through the checkout health-check / stale-retry path and
/// count **zero** failed exchanges — only unrecovered exchanges belong
/// in `GossipRoundReport::failed`.
#[test]
fn stale_pooled_connection_recovers_without_counting_failed() {
    let mut cfg = service_cfg();
    cfg.gossip.exchange_deadline_ms = 2_000;
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    assert!(opts.pool_connections > 0);

    // Server S1 at member index 0; its own remote-peer entry is a dead
    // placeholder (it never initiates — round_interval is 0 and the
    // test never steps it).
    let placeholder = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let s1_transport = TcpTransport::bind_with("127.0.0.1:0", opts.clone()).unwrap();
    let addr = s1_transport.listen_addr().unwrap();
    let s1 = Node::builder()
        .config(cfg.clone())
        .self_index(0)
        .transport(s1_transport)
        .remote_peer(placeholder)
        .build()
        .unwrap();

    // Initiator I at member index 1, client-only, transport kept shared
    // so the test can read its pool counters.
    let it = Arc::new(TcpTransport::connect_only_with(opts.clone()).unwrap());
    let i = Node::builder()
        .config(cfg.clone())
        .self_index(1)
        .transport_shared(it.clone())
        .remote_peer(addr)
        .build()
        .unwrap();
    let mut w = i.writer();
    w.insert_batch(&(1..=500).map(f64::from).collect::<Vec<_>>());
    w.flush();
    i.flush();

    // First exchange: fresh connect, then the connection is pooled.
    let r1 = i.step().unwrap();
    assert_eq!(r1.exchanges, 1, "first exchange must complete");
    assert_eq!(r1.failed, 0);
    assert_eq!(it.pool_stats().fresh_connects, 1);
    assert_eq!(it.pooled_connections(addr), 1, "connection was pooled");

    // The server goes away (its serve loop closes every connection) and
    // a replacement binds the same address.
    s1.shutdown();
    let s2 = Node::builder()
        .config(cfg.clone())
        .self_index(0)
        .transport(TcpTransport::bind_with(addr, opts.clone()).unwrap())
        .remote_peer(placeholder)
        .build()
        .unwrap();
    // Bounded-deadline poll instead of a fixed "let the FINs land"
    // sleep: wait until the replacement server accepts connections. The
    // old server closed its sockets before s2 could even bind, so by
    // the time a fresh connect round-trips, the FIN has reached the
    // pooled socket — and even a FIN that arrives mid-exchange is
    // classified stale and retried, never counted failed.
    common::wait_tcp_ready(addr, Duration::from_secs(10));

    // Second exchange: the pooled connection is stale; the transport
    // must fall back to a fresh connect and the round must count one
    // *successful* exchange and zero failures.
    let r2 = i.step().unwrap();
    assert_eq!(
        r2.failed, 0,
        "a recovered pool failure must not count as a failed exchange"
    );
    assert_eq!(r2.exchanges, 1, "the retry must complete the exchange");
    let stats = it.pool_stats();
    assert!(
        stats.stale_discarded >= 1,
        "the dead pooled connection was discarded: {stats:?}"
    );
    assert_eq!(stats.fresh_connects, 2, "one fresh connect per server");

    drop(w);
    i.shutdown();
    s2.shutdown();
}

/// Near convergence a delta exchange ships a few dozen bytes where full
/// frames ship ~16 KiB: the second exchange of an unchanged pair must be
/// over an order of magnitude smaller with deltas on, and roughly the
/// same size with deltas off.
#[test]
fn near_converged_delta_exchanges_shrink_wire_bytes() {
    let run_pair = |delta: bool| -> (usize, usize) {
        let mut cfg = service_cfg();
        cfg.gossip.exchange_deadline_ms = 2_000;
        cfg.gossip.delta_exchanges = delta;
        let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
        let placeholder = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let server = Node::builder()
            .config(cfg.clone())
            .self_index(0)
            .transport(TcpTransport::bind_with("127.0.0.1:0", opts.clone()).unwrap())
            .remote_peer(placeholder)
            .build()
            .unwrap();
        let addr = server.listen_addr().unwrap();
        let client = Node::builder()
            .config(cfg.clone())
            .self_index(1)
            .transport(TcpTransport::connect_only_with(opts).unwrap())
            .remote_peer(addr)
            .build()
            .unwrap();
        let mut w = client.writer();
        w.insert_batch(&(1..=2_000).map(f64::from).collect::<Vec<_>>());
        w.flush();
        client.flush();

        let r1 = client.step().unwrap();
        assert_eq!(r1.exchanges, 1, "first exchange (full frames)");
        assert_eq!(r1.pool.fresh_connects, 1, "{:?}", r1.pool);
        assert_eq!(r1.pool.full_pushes, 1, "first push is always full");
        // No new epoch between steps: the pair's states are already the
        // shared average, so the second exchange changes nothing.
        let r2 = client.step().unwrap();
        assert_eq!(r2.exchanges, 1, "second exchange");
        assert_eq!(r2.failed, 0);
        // ISSUE 5 satellite: per-round pool/frame-mix telemetry in the
        // report — dashboards no longer pull PoolStats off the transport.
        assert_eq!(r2.pool.reused, 1, "pooled reuse visible per round");
        assert_eq!(r2.pool.fresh_connects, 0, "{:?}", r2.pool);
        if delta {
            assert_eq!(r2.pool.delta_pushes, 1, "{:?}", r2.pool);
            assert_eq!(r2.pool.full_pushes, 0, "{:?}", r2.pool);
        } else {
            assert_eq!(r2.pool.delta_pushes, 0, "{:?}", r2.pool);
            assert_eq!(r2.pool.full_pushes, 1, "{:?}", r2.pool);
        }
        drop(w);
        client.shutdown();
        server.shutdown();
        (r1.bytes, r2.bytes)
    };

    let (full_first, delta_second) = run_pair(true);
    assert!(
        delta_second * 10 < full_first,
        "near-converged delta exchange must be >10x smaller: \
         first={full_first}B second={delta_second}B"
    );

    let (_, full_second) = run_pair(false);
    assert!(
        full_second * 2 > full_first,
        "with deltas off the steady-state exchange stays full-size: \
         first={full_first}B second={full_second}B"
    );
    assert!(
        delta_second * 10 < full_second,
        "delta steady-state must be >10x below full steady-state: \
         delta={delta_second}B full={full_second}B"
    );
}

/// A delta push naming a baseline the server does not hold draws a
/// `BaselineMismatch` reject, leaves the server's state bit-for-bit
/// untouched, and keeps the connection alive so the full-frame fallback
/// lands on the very same socket — the in-protocol downgrade path.
#[test]
fn stale_baseline_delta_push_falls_back_on_same_connection() {
    use duddsketch::sketch::{
        decode_exchange, delta_payload, encode_exchange_delta_push, encode_exchange_push,
        peer_state_fingerprint, ExchangeFrame, RejectReason,
    };

    let mut cfg = service_cfg();
    cfg.gossip.exchange_deadline_ms = 2_000;
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let node = Node::builder()
        .config(cfg)
        .self_index(0)
        .transport(TcpTransport::bind_with("127.0.0.1:0", opts).unwrap())
        .remote_peer(dead_addr)
        .build()
        .unwrap();
    let addr = node.listen_addr().unwrap();
    let mut w = node.writer();
    w.insert_batch(&(1..=400).map(f64::from).collect::<Vec<_>>());
    w.flush();
    node.flush();
    node.step(); // seed epoch 1 into the protocol state
    let gen = node.global_view().unwrap().generation();
    let before = node.global_view().unwrap().state().clone();

    let read_reply = |s: &mut TcpStream| -> Vec<u8> {
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut buf).unwrap();
        buf
    };

    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_millis(2_000))).unwrap();

    // A structurally valid delta push against a baseline only we hold.
    let alien = PeerState::init(9, &[1.0, 2.0, 3.0], 0.001, 1024).unwrap();
    let fp = peer_state_fingerprint(&alien);
    let delta = delta_payload(&alien, fp, &alien).unwrap();
    let frame = encode_exchange_delta_push(gen, &delta);
    s.write_all(&(frame.len() as u32).to_le_bytes()).unwrap();
    s.write_all(&frame).unwrap();
    match decode_exchange(&read_reply(&mut s)).unwrap() {
        ExchangeFrame::Reject { reason, .. } => {
            assert_eq!(reason, RejectReason::BaselineMismatch);
        }
        other => panic!("expected a baseline-mismatch reject, got {other:?}"),
    }
    let after = node.global_view().unwrap().state().clone();
    assert_eq!(after.n_tilde.to_bits(), before.n_tilde.to_bits());
    assert_eq!(after.q_tilde.to_bits(), before.q_tilde.to_bits());
    assert_eq!(
        after.sketch.positive_store().entries(),
        before.sketch.positive_store().entries(),
        "a rejected delta must never touch the serve state"
    );

    // Same socket, full frame: the exchange completes.
    let frame = encode_exchange_push(gen, &alien);
    s.write_all(&(frame.len() as u32).to_le_bytes()).unwrap();
    s.write_all(&frame).unwrap();
    match decode_exchange(&read_reply(&mut s)).unwrap() {
        ExchangeFrame::Reply { generation, state } => {
            assert_eq!(generation, gen);
            assert_eq!(state.id, 9, "reply echoes the initiator's id");
        }
        other => panic!("expected a reply on the same connection, got {other:?}"),
    }

    drop(w);
    node.shutdown();
}
