//! Integration: the continuous service-driven gossip loop.
//!
//! Acceptance (ISSUE 2): with ingest live, the global-view quantiles at
//! q ∈ {0.5, 0.9, 0.99} converge to within the configured α
//! relative-error bound of a sequential UDDSketch over the **union**
//! stream — for a fleet of real services gossiping while their writers
//! are still inserting, in both manual-stepping and background-thread
//! modes.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::{GossipLoopConfig, ServiceConfig};
use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::metrics::relative_error;
use duddsketch::service::{GossipLoop, GossipMember, QuantileService};
use duddsketch::sketch::UddSketch;
use std::sync::Arc;
use std::time::Duration;

const ACCEPT_QS: [f64; 3] = [0.5, 0.9, 0.99];

fn service_cfg(shards: usize) -> ServiceConfig {
    let mut c = ServiceConfig::default();
    c.shards = shards;
    c.batch_size = 512;
    c
}

/// Step until the loop reports `streak` consecutive converged rounds
/// (bounded); returns the rounds it took.
fn step_to_convergence(gl: &GossipLoop, streak: usize, max_rounds: usize) -> usize {
    let mut ok = 0usize;
    for k in 1..=max_rounds {
        let r = gl.step();
        ok = if r.converged { ok + 1 } else { 0 };
        if ok >= streak {
            return k;
        }
    }
    panic!("loop did not converge within {max_rounds} rounds");
}

/// The acceptance test: three live services ingest concurrently while
/// the fleet gossips; after the streams end the global view of *every*
/// service converges to the sequential union sketch within α.
#[test]
fn global_view_converges_to_union_while_ingest_continues() {
    let nodes = 3;
    let items = 12_000;
    let master = duddsketch::rng::default_rng(42);
    let datasets: Vec<Vec<f64>> = (0..nodes)
        .map(|i| peer_dataset(DatasetKind::Exponential, i, items, &master))
        .collect();

    let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    for d in &datasets {
        seq.extend(d);
    }

    let services: Vec<Arc<QuantileService>> = (0..nodes)
        .map(|_| QuantileService::start_shared(service_cfg(2)).unwrap())
        .collect();
    let members: Vec<GossipMember> = services
        .iter()
        .map(|s| GossipMember::service(s.clone()))
        .collect();
    let gl = GossipLoop::start(GossipLoopConfig::default(), members).unwrap();

    // Live ingest: every service consumes its stream in chunks, with
    // gossip rounds interleaved — under restart-free churn each epoch
    // advance is folded into the averaged states in place (no restart),
    // exactly the paper's "tracking while ingesting".
    let chunks: Vec<Vec<&[f64]>> = datasets.iter().map(|d| d.chunks(3_000).collect()).collect();
    let mut carries = 0usize;
    for step in 0..4 {
        for (svc, chunks) in services.iter().zip(&chunks) {
            let mut w = svc.writer();
            w.insert_batch(chunks[step]);
            w.flush();
            svc.flush();
        }
        let r = gl.step();
        assert!(
            !r.reseeded,
            "restart-free: insert-only ingest must never restart the protocol"
        );
        if r.epoch_carried {
            carries += 1;
        }
        gl.step();
    }
    assert!(carries >= 3, "live ingest must keep carrying epochs ({carries})");

    // Streams done: converge on the final epochs and verify every
    // service member's view against the union.
    step_to_convergence(&gl, 3, 400);
    for i in 0..nodes {
        let v = gl.member_view(i);
        assert_eq!(v.epoch(), 4, "member {i} seeded from a stale epoch");
        assert_eq!(v.estimated_peers(), nodes as f64, "member {i} fleet size");
        assert_eq!(
            v.estimated_total(),
            (nodes * items) as f64,
            "member {i} union length"
        );
        for q in ACCEPT_QS {
            let est = v.query(q).unwrap();
            let truth = seq.quantile(q).unwrap();
            let re = relative_error(est, truth);
            assert!(
                re <= seq.alpha() + 1e-9,
                "member {i} q={q}: global view {est} vs sequential {truth} \
                 (re {re} > alpha {})",
                seq.alpha()
            );
        }
    }
    drop(gl);
    for svc in services {
        Arc::try_unwrap(svc).unwrap().shutdown();
    }
}

/// Fully background mode: service epoch ticker + gossip loop thread,
/// writers on their own threads — no manual stepping anywhere. The view
/// must converge to the union within a bounded wall-clock window.
#[test]
fn background_loop_converges_with_live_tickers() {
    let items = 20_000;
    let master = duddsketch::rng::default_rng(7);
    let data_a = peer_dataset(DatasetKind::Uniform, 0, items, &master);
    let data_b = peer_dataset(DatasetKind::Uniform, 1, items, &master);

    let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    seq.extend(&data_a);
    seq.extend(&data_b);

    let mut cfg = service_cfg(2);
    cfg.epoch_interval_ms = 10;
    let svc_a = QuantileService::start_shared(cfg.clone()).unwrap();
    let svc_b = QuantileService::start_shared(cfg).unwrap();

    let mut gcfg = GossipLoopConfig::default();
    gcfg.round_interval_ms = 5;
    let gl = GossipLoop::start(
        gcfg,
        vec![
            GossipMember::service(svc_a.clone()),
            GossipMember::service(svc_b.clone()),
        ],
    )
    .unwrap();

    std::thread::scope(|scope| {
        for (svc, data) in [(&svc_a, &data_a), (&svc_b, &data_b)] {
            let mut w = svc.writer();
            scope.spawn(move || {
                for chunk in data.chunks(2_000) {
                    w.insert_batch(chunk);
                    w.flush();
                    std::thread::sleep(Duration::from_millis(5));
                }
            });
        }
    });

    // Writers are done; tickers fold the tails, the loop carries the
    // tail epochs and converges — all in the background.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let v = gl.view();
        if v.converged() && v.estimated_total() == (2 * items) as f64 {
            for q in ACCEPT_QS {
                let est = v.query(q).unwrap();
                let truth = seq.quantile(q).unwrap();
                let re = relative_error(est, truth);
                assert!(
                    re <= seq.alpha() + 1e-9,
                    "q={q}: {est} vs {truth} (re {re})"
                );
            }
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "background loop never converged on the full union \
             (round {}, total {})",
            v.round(),
            v.estimated_total()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let final_view = gl.shutdown();
    assert!(final_view.round() > 0);
    Arc::try_unwrap(svc_a).unwrap().shutdown();
    Arc::try_unwrap(svc_b).unwrap().shutdown();
}

/// One live service among simulated remote peers: the fleet-size and
/// union estimates still come out right, and the converged view answers
/// exactly like the sequential union sketch.
#[test]
fn live_service_among_static_peers() {
    let nodes = 8;
    let items = 4_000;
    let master = duddsketch::rng::default_rng(11);
    let datasets: Vec<Vec<f64>> = (0..nodes)
        .map(|i| peer_dataset(DatasetKind::Normal, i, items, &master))
        .collect();

    let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    for d in &datasets {
        seq.extend(d);
    }

    let svc = QuantileService::start_shared(service_cfg(2)).unwrap();
    let mut w = svc.writer();
    w.insert_batch(&datasets[0]);
    w.flush();
    svc.flush();

    let mut members = vec![GossipMember::service(svc.clone())];
    for d in &datasets[1..] {
        members.push(GossipMember::from_dataset(d, 0.001, 1024).unwrap());
    }
    let gl = GossipLoop::start(GossipLoopConfig::default(), members).unwrap();
    let rounds = step_to_convergence(&gl, 3, 400);
    let v = gl.view();
    assert_eq!(v.estimated_peers(), nodes as f64);
    assert_eq!(v.estimated_total(), (nodes * items) as f64);
    for q in ACCEPT_QS {
        let est = v.query(q).unwrap();
        let truth = seq.quantile(q).unwrap();
        let re = relative_error(est, truth);
        assert!(re <= seq.alpha() + 1e-9, "q={q} after {rounds} rounds: re {re}");
    }
    drop(gl);
    Arc::try_unwrap(svc).unwrap().shutdown();
}
