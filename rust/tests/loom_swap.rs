//! Loom model of the `ArcSwapCell` reclamation scheme
//! (`rust/src/service/swap.rs`).
//!
//! The real cell cannot run under loom directly — `Arc::into_raw` /
//! `increment_strong_count` bypass loom's instrumented types — so this
//! models the algorithm's *shape* with loom atomics over an arena of
//! slots and checks its central claim under every interleaving:
//!
//! > an entry is freed only after the writer observes `readers == 0`
//! > *after* unpublishing it, therefore no reader between its
//! > `readers += 1` announcement and its refcount bump can ever
//! > resurrect a freed entry.
//!
//! The model intentionally mirrors the ordering decisions of the real
//! code (all `SeqCst`, announce-before-pointer-read on the reader side,
//! swap-before-trim on the writer side). Weakening any of them — e.g.
//! reading the pointer before bumping `readers` — makes this test fail.
//!
//! Run with (loom is a CI-only dev-dependency, absent offline):
//!
//! ```text
//! cargo add loom@0.7 --dev
//! RUSTFLAGS="--cfg loom" cargo test -p duddsketch --test loom_swap --release
//! ```
//!
//! Without `--cfg loom` the whole target compiles to nothing, so plain
//! `cargo test` is unaffected.
#![cfg(loom)]

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::{Arc, Mutex};
use loom::thread;

const SLOTS: usize = 3;

/// Arena model of `ArcSwapCell`: `ptr` holds a slot index instead of a
/// raw pointer, `strong[i]` models `Arc` strong counts, and `freed[i]`
/// models actual deallocation (monotonic; resurrecting a freed slot is
/// the use-after-free the real scheme must exclude).
struct Model {
    ptr: AtomicUsize,
    readers: AtomicUsize,
    strong: [AtomicUsize; SLOTS],
    freed: [AtomicUsize; SLOTS],
    retired: Mutex<Vec<usize>>,
}

impl Model {
    fn new() -> Self {
        Model {
            ptr: AtomicUsize::new(0),
            readers: AtomicUsize::new(0),
            strong: [
                AtomicUsize::new(1), // slot 0 published at construction
                AtomicUsize::new(0),
                AtomicUsize::new(0),
            ],
            freed: [
                AtomicUsize::new(0),
                AtomicUsize::new(0),
                AtomicUsize::new(0),
            ],
            retired: Mutex::new(vec![0]),
        }
    }

    /// `ArcSwapCell::load`: announce, read pointer, resurrect, retreat.
    fn load(&self) -> usize {
        self.readers.fetch_add(1, Ordering::SeqCst);
        let i = self.ptr.load(Ordering::SeqCst);
        // the "SAFETY" claim of the real load(): the slot the reader
        // resurrects must still be backed by a strong handle
        assert_eq!(
            self.freed[i].load(Ordering::SeqCst),
            0,
            "reader resurrected a freed slot — reclamation raced the load window"
        );
        self.strong[i].fetch_add(1, Ordering::SeqCst);
        self.readers.fetch_sub(1, Ordering::SeqCst);
        // the caller's Arc<T> drops immediately in this model
        self.strong[i].fetch_sub(1, Ordering::SeqCst);
        i
    }

    /// `ArcSwapCell::store`: retain, swap, then quiescent trim. The real
    /// code spins up to 1024 times waiting for `readers == 0`; one
    /// attempt is the same decision procedure with fewer interleavings.
    fn store(&self, new: usize) {
        let mut retired = self.retired.lock().unwrap();
        retired.push(new);
        self.strong[new].fetch_add(1, Ordering::SeqCst);
        self.ptr.swap(new, Ordering::SeqCst);
        if self.readers.load(Ordering::SeqCst) == 0 {
            retired.retain(|&i| {
                if i == new || self.strong[i].load(Ordering::SeqCst) > 1 {
                    true
                } else {
                    self.strong[i].fetch_sub(1, Ordering::SeqCst);
                    self.freed[i].store(1, Ordering::SeqCst);
                    false
                }
            });
        }
    }
}

#[test]
fn reader_never_resurrects_freed_slot() {
    loom::model(|| {
        let m = Arc::new(Model::new());
        let reader = {
            let m = m.clone();
            thread::spawn(move || {
                let a = m.load();
                let b = m.load();
                (a, b)
            })
        };
        m.store(1);
        m.store(2);
        let (a, b) = reader.join().unwrap();
        // each load observed some published slot; the assert inside
        // load() already failed if reclamation raced it
        assert!(a < SLOTS && b < SLOTS);
    });
}

#[test]
fn quiescent_trim_frees_unreachable_slot() {
    // Single-threaded sanity inside the model: after two stores with no
    // concurrent reader, slot 1 must actually be reclaimed (the scheme
    // is not allowed to leak forever when quiescence is observable).
    loom::model(|| {
        let m = Model::new();
        m.store(1);
        m.store(2);
        assert_eq!(m.freed[0].load(Ordering::SeqCst), 1, "slot 0 leaked");
        assert_eq!(m.freed[1].load(Ordering::SeqCst), 1, "slot 1 leaked");
        assert_eq!(m.ptr.load(Ordering::SeqCst), 2);
        // only the currently published slot stays pinned
        assert_eq!(m.retired.lock().unwrap().len(), 1);
    });
}
