//! Integration: the sharded ingest + snapshot-query service against the
//! sequential reference.
//!
//! The load-bearing guarantee (mergeability, Definition 7): a service
//! snapshot answers quantile queries **identically** to one sequential
//! `UddSketch` fed the same stream — sharding, batching, epoch folds,
//! and collapse-lineage alignment change nothing — and therefore carries
//! the same α relative-value-error guarantee.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::ServiceConfig;
use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::metrics::relative_error;
use duddsketch::rng::default_rng;
use duddsketch::service::QuantileService;
use duddsketch::sketch::{ExactQuantiles, UddSketch};
use std::time::Duration;

const ACCEPT_QS: [f64; 3] = [0.01, 0.5, 0.99];

fn cfg(shards: usize) -> ServiceConfig {
    let mut c = ServiceConfig::default();
    c.shards = shards;
    c.batch_size = 512;
    c
}

/// Acceptance: for each data workload, ingest through 4 shards across
/// several epochs; the final snapshot's quantiles equal the sequential
/// sketch's at q ∈ {0.01, 0.5, 0.99}, and both honour the α bound vs the
/// exact oracle.
#[test]
fn snapshot_quantiles_equal_sequential_sketch() {
    for kind in [
        DatasetKind::Uniform,
        DatasetKind::Exponential,
        DatasetKind::Adversarial,
        DatasetKind::Normal,
    ] {
        let master = default_rng(42);
        let data = peer_dataset(kind, 0, 40_000, &master);

        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        seq.extend(&data);

        let svc = QuantileService::start(cfg(4)).unwrap();
        let mut w = svc.writer();
        // Several epochs: flush mid-stream so the fold path (delta merge +
        // accumulator) is exercised, not just one big drain.
        for chunk in data.chunks(9_000) {
            w.insert_batch(chunk);
            w.flush();
            svc.flush();
        }
        drop(w);
        let snap = svc.shutdown();

        assert_eq!(snap.count(), data.len() as f64, "{kind:?}: lost items");
        assert_eq!(
            snap.alpha(),
            seq.alpha(),
            "{kind:?}: collapse lineages diverged"
        );
        let exact = ExactQuantiles::new(&data);
        for q in ACCEPT_QS {
            let s = snap.quantile(q).unwrap();
            let t = seq.quantile(q).unwrap();
            assert_eq!(s, t, "{kind:?} q={q}: service {s} vs sequential {t}");
            // Same α guarantee as the sequential algorithm.
            let truth = exact.quantile(q).unwrap();
            let re = relative_error(s, truth);
            assert!(
                re <= snap.alpha() + 1e-9,
                "{kind:?} q={q}: re {re} > alpha {}",
                snap.alpha()
            );
        }
    }
}

/// Concurrent producers: the union stream is what the snapshot
/// summarizes, independent of interleaving (permutation invariance).
#[test]
fn concurrent_writers_fold_exactly() {
    let master = default_rng(7);
    let parts: Vec<Vec<f64>> = (0..6)
        .map(|k| peer_dataset(DatasetKind::Exponential, k, 10_000, &master))
        .collect();

    let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    for p in &parts {
        seq.extend(p);
    }

    let svc = QuantileService::start(cfg(3)).unwrap();
    std::thread::scope(|scope| {
        for p in &parts {
            let mut w = svc.writer();
            scope.spawn(move || {
                w.insert_batch(p);
                w.flush();
            });
        }
    });
    let snap = svc.flush();
    assert_eq!(snap.count(), 60_000.0);
    for q in ACCEPT_QS {
        assert_eq!(snap.quantile(q).unwrap(), seq.quantile(q).unwrap(), "q={q}");
    }
    svc.shutdown();
}

/// Turnstile deletes through the sharded path: a delete may land on a
/// different shard than its insert; weights still cancel exactly in the
/// epoch fold.
#[test]
fn turnstile_deletes_match_sequential() {
    let master = default_rng(11);
    let data = peer_dataset(DatasetKind::Uniform, 0, 20_000, &master);
    let (keep, gone) = data.split_at(12_000);

    let mut seq: UddSketch = UddSketch::new(0.001, 4096).unwrap();
    seq.extend(&data);
    for &x in gone {
        seq.delete(x);
    }

    let mut c = cfg(4);
    c.max_buckets = 4096;
    let svc = QuantileService::start(c).unwrap();
    let mut w = svc.writer();
    w.insert_batch(&data);
    w.flush();
    svc.flush(); // epoch boundary between inserts and deletes
    for &x in gone {
        w.delete(x);
    }
    w.flush();
    drop(w);
    let snap = svc.shutdown();

    assert_eq!(snap.count(), keep.len() as f64);
    for q in ACCEPT_QS {
        assert_eq!(snap.quantile(q).unwrap(), seq.quantile(q).unwrap(), "q={q}");
    }
}

/// Sliding-window mode serves exactly the last `k` epoch intervals.
#[test]
fn windowed_snapshot_covers_recent_epochs_only() {
    let master = default_rng(13);
    let data = peer_dataset(DatasetKind::Exponential, 0, 25_000, &master);
    let chunks: Vec<&[f64]> = data.chunks(5_000).collect();
    assert_eq!(chunks.len(), 5);

    let mut c = cfg(2);
    c.window_slots = 3;
    let svc = QuantileService::start(c).unwrap();
    let mut w = svc.writer();
    for chunk in &chunks {
        w.insert_batch(chunk);
        w.flush();
        svc.flush();
    }
    drop(w);
    let snap = svc.snapshot();

    // Window = epochs 3..=5 = chunks[2..5].
    assert_eq!(snap.window(), Some((3, 5)));
    let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
    for chunk in &chunks[2..] {
        seq.extend(chunk);
    }
    assert_eq!(snap.count(), 15_000.0);
    for q in ACCEPT_QS {
        assert_eq!(snap.quantile(q).unwrap(), seq.quantile(q).unwrap(), "q={q}");
    }
    // Lifetime ops still counts evicted epochs.
    assert_eq!(snap.ops(), 25_000);
    svc.shutdown();
}

/// Windowed-mode edge cases at the service level: queries on an empty
/// window (before any epoch, and again after idle epochs aged all data
/// out) must refuse cleanly, and idle flushes must keep advancing the
/// window.
#[test]
fn windowed_service_empty_window_queries() {
    let mut c = cfg(2);
    c.window_slots = 2;
    let svc = QuantileService::start(c).unwrap();

    // Before the first epoch: empty snapshot, no window, query refused.
    let snap = svc.snapshot();
    assert!(snap.is_empty());
    assert_eq!(snap.window(), None);
    assert!(snap.quantile(0.5).is_err());

    // One epoch of data.
    let mut w = svc.writer();
    w.insert_batch(&[1.0, 2.0, 3.0, 4.0]);
    w.flush();
    let snap = svc.flush();
    assert_eq!(snap.count(), 4.0);
    assert_eq!(snap.window(), Some((1, 1)));

    // Two idle epochs age the data out of the 2-slot window entirely —
    // unlike cumulative mode, windowed idle flushes must keep publishing.
    svc.flush();
    let snap = svc.flush();
    assert_eq!(snap.window(), Some((2, 3)));
    assert_eq!(snap.count(), 0.0, "evicted data survived idle epochs");
    assert!(
        snap.quantile(0.5).is_err(),
        "empty window must refuse queries"
    );
    // Lifetime ops still remembers the evicted stream.
    assert_eq!(snap.ops(), 4);
    drop(w);
    svc.shutdown();
}

/// Ring wrap-around through the service: many more epochs than slots;
/// every published snapshot agrees with a sequential sketch over the
/// same K-epoch slice.
#[test]
fn windowed_service_agrees_with_sequential_slice_across_wraps() {
    let master = default_rng(23);
    let data = peer_dataset(DatasetKind::Uniform, 0, 22_000, &master);
    let chunks: Vec<&[f64]> = data.chunks(2_000).collect();
    assert_eq!(chunks.len(), 11);
    let k = 3usize;

    let mut c = cfg(2);
    c.window_slots = k;
    let svc = QuantileService::start(c).unwrap();
    let mut w = svc.writer();
    for (e, chunk) in chunks.iter().enumerate() {
        w.insert_batch(chunk);
        w.flush();
        let snap = svc.flush();

        // Sequential sketch over exactly the chunks the window covers.
        let lo = e.saturating_sub(k - 1);
        let mut seq: UddSketch = UddSketch::new(0.001, 1024).unwrap();
        for slice in &chunks[lo..=e] {
            seq.extend(slice);
        }
        assert_eq!(snap.window(), Some((lo as u64 + 1, e as u64 + 1)));
        assert_eq!(snap.count(), seq.count(), "epoch {}", e + 1);
        for q in ACCEPT_QS {
            assert_eq!(
                snap.quantile(q).unwrap(),
                seq.quantile(q).unwrap(),
                "epoch {} q={q}",
                e + 1
            );
        }
    }
    drop(w);
    svc.shutdown();
}

/// End-to-end concurrency: background epochs publish while readers query
/// and writers ingest; epochs advance monotonically and every snapshot
/// is internally consistent.
#[test]
fn readers_never_block_and_epochs_advance() {
    let mut c = cfg(2);
    c.epoch_interval_ms = 5;
    let svc = QuantileService::start(c).unwrap();

    let master = default_rng(17);
    let data = peer_dataset(DatasetKind::Uniform, 0, 50_000, &master);

    std::thread::scope(|scope| {
        let svc_ref = &svc;
        // Readers: epoch must never go backwards; counts never negative.
        let mut readers = Vec::new();
        for _ in 0..3 {
            readers.push(scope.spawn(move || {
                let mut last_epoch = 0u64;
                for _ in 0..2_000 {
                    let snap = svc_ref.snapshot();
                    assert!(snap.epoch() >= last_epoch, "epoch went backwards");
                    last_epoch = snap.epoch();
                    if !snap.is_empty() {
                        let p50 = snap.quantile(0.5).unwrap();
                        assert!(p50.is_finite() && p50 > 0.0);
                    }
                }
                last_epoch
            }));
        }
        // Writer alongside.
        let mut w = svc_ref.writer();
        w.insert_batch(&data);
        w.flush();
        drop(w);
        for r in readers {
            r.join().unwrap();
        }
    });

    // Wait (bounded) for the ticker to fold everything.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while svc.snapshot().count() < 50_000.0 {
        assert!(
            std::time::Instant::now() < deadline,
            "ticker never folded the stream (count {})",
            svc.snapshot().count()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let fin = svc.shutdown();
    assert_eq!(fin.count(), 50_000.0);
}
