//! Integration: the deterministic fleet simulator (ISSUE 7).
//!
//! Acceptance (scaled down from the CI `sim-fleet` lane so the suite
//! stays fast): a simulated fleet running the *production* gossip loop
//! and membership plane over `SimTransport` converges to the exact
//! union oracle while members join, crash, rejoin and a partition heals
//! mid-run — and the same `(scenario, seed)` pair reproduces the event
//! trace and JSON log byte for byte.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::sim::{EventAction, Scenario, ScheduledEvent, SimFleet};

/// A fleet small enough to run in well under a second but large enough
/// that joins, a crash wave, and a partition all have someone to hit.
fn storm_scenario() -> Scenario {
    let mut s = Scenario::default();
    s.name = "integration-storm".into();
    s.members = 16;
    s.rounds = 40;
    s.items_per_member = 120;
    s.alpha = 0.01;
    s.max_buckets = 256;
    // Dead-detection must fit the run: suspicion outlives one round,
    // death two, leaving plenty of post-churn rounds to re-mix.
    s.suspect_after_ms = 1_000;
    s.events = vec![
        ScheduledEvent {
            round: 4,
            action: EventAction::Join(2),
        },
        ScheduledEvent {
            round: 8,
            action: EventAction::Crash(2),
        },
        ScheduledEvent {
            round: 12,
            action: EventAction::Partition(0.25),
        },
        ScheduledEvent {
            round: 16,
            action: EventAction::Heal,
        },
    ];
    s
}

/// The acceptance scenario end to end: the union estimate lands within
/// the oracle tolerance and stays there, with every membership event
/// visible in the per-round log.
#[test]
fn churny_fleet_converges_to_union_oracle() {
    let report = SimFleet::new(storm_scenario(), 33).unwrap().run().unwrap();

    assert_eq!(report.rounds.len(), 40);
    assert_eq!(report.members_initial, 16);
    assert_eq!(report.members_peak, 18, "the round-4 join wave must register");

    // Post-event alive counts, straight from the per-round log
    // (events apply before the round's exchanges).
    assert_eq!(report.rounds[3].alive, 18, "after the join wave");
    assert_eq!(report.rounds[7].alive, 16, "after the crash wave");
    assert!(
        !report.rounds[3].events.is_empty() && !report.rounds[7].events.is_empty(),
        "scheduled events must be logged on their round"
    );

    // The partition must actually refuse traffic, and heal.
    assert!(report.net.refused > 0, "partition refused no connections");

    let converged = report
        .converged_round
        .expect("fleet must converge after the partition heals");
    assert!(
        converged > 8,
        "convergence cannot predate the crash wave (round {converged})"
    );
    assert!(report.final_max_rel_err <= report.tol);
    assert!(report.rounds.last().unwrap().within_tol);
    assert!(report.reference_rounds > 0);
}

/// Determinism is the simulator's contract: the same seed reproduces
/// the trace and the JSON log byte for byte; a different seed diverges.
#[test]
fn same_seed_reproduces_trace_and_json_bytes() {
    let a = SimFleet::new(storm_scenario(), 21).unwrap().run().unwrap();
    let b = SimFleet::new(storm_scenario(), 21).unwrap().run().unwrap();
    assert_eq!(a.trace_text(), b.trace_text(), "trace must be byte-identical");
    assert_eq!(a.to_json(), b.to_json(), "JSON log must be byte-identical");

    let c = SimFleet::new(storm_scenario(), 22).unwrap().run().unwrap();
    assert_ne!(
        a.trace_text(),
        c.trace_text(),
        "a different seed must produce a different trace"
    );
}

/// The ISSUE 9 join-storm, scaled down from the 1000-member CI lane: a
/// staggered join storm on clean links, against the same-seed no-churn
/// fleet already at the final size.
fn join_storm_scaled(restart_free: bool) -> Scenario {
    let mut s = Scenario::default();
    s.name = "join-storm-scaled".into();
    s.members = 20;
    s.rounds = 32;
    s.items_per_member = 100;
    s.alpha = 0.01;
    s.max_buckets = 256;
    s.restart_free = restart_free;
    // Ten joins, one before every other round, done by round 23 so the
    // tail can settle.
    s.events = (0..10)
        .map(|k| ScheduledEvent {
            round: 5 + 2 * k,
            action: EventAction::Join(1),
        })
        .collect();
    s
}

/// Restart-free churn acceptance (ISSUE 9): under the join storm the
/// protocol generation never bumps, each join costs O(1) extra wire
/// bytes — no round's exchange-plane bytes exceed the same-seed
/// no-churn baseline's by more than two full frames — and the fleet
/// still converges to the union-of-alive oracle within
/// `max(2·theorem2_bound, α)`.
#[test]
fn join_storm_is_generation_quiet_and_costs_o1_bytes_per_join() {
    let storm = SimFleet::new(join_storm_scaled(true), 77).unwrap().run().unwrap();

    // (a) Joins are free: no node ever leaves generation 1.
    for r in &storm.rounds {
        assert_eq!(
            r.generation, 1,
            "restart-free joins must not bump the generation (round {})",
            r.round
        );
    }
    assert_eq!(storm.members_peak, 30, "all ten joiners must register");

    // (b) O(1) bytes per join: compare round for round against the
    // no-churn fleet already at the final size, under the same seed
    // (identical per-ordinal datasets). The slack is two of the
    // baseline's largest full frames — the join handshake itself plus
    // one first exchange, never a fleet-wide anything.
    let mut base_scenario = join_storm_scaled(true);
    base_scenario.name = "join-storm-base".into();
    base_scenario.members = 30;
    base_scenario.events.clear();
    let base = SimFleet::new(base_scenario, 77).unwrap().run().unwrap();
    let frame = base
        .rounds
        .iter()
        .map(|r| r.bytes / r.exchanges.max(1))
        .max()
        .unwrap();
    for (s_r, b_r) in storm.rounds.iter().zip(&base.rounds) {
        assert!(
            s_r.bytes <= b_r.bytes + 2 * frame,
            "round {}: storm bytes {} exceed no-churn baseline {} + 2 frames ({frame}B each)",
            s_r.round,
            s_r.bytes,
            b_r.bytes,
        );
    }

    // (c) Correctness is not traded away: the sampled union estimates
    // converge within the oracle bound and stay there.
    let converged = storm.converged_round.expect("join storm must converge");
    assert!(converged <= 32, "converged_round {converged} out of range");
    assert!(storm.final_max_rel_err <= storm.tol);
    assert!(storm.rounds.last().unwrap().within_tol);

    // Determinism holds under the storm too (the CI lane re-asserts
    // this at 1000 members by byte-diffing two full traces).
    let again = SimFleet::new(join_storm_scaled(true), 77).unwrap().run().unwrap();
    assert_eq!(storm.trace_text(), again.trace_text());
}

/// The A/B contrast pinning what restart-free buys: the identical join
/// storm under the PR 5 restart-everything rules bumps the generation
/// mid-run (every join re-anchors the whole fleet).
#[test]
fn join_storm_with_restart_free_off_bumps_generations() {
    let report = SimFleet::new(join_storm_scaled(false), 77).unwrap().run().unwrap();
    assert!(
        report.rounds.iter().any(|r| r.generation > 1),
        "with restart_free off, joins must restart the protocol"
    );
}

/// Fail&Stop-style rejoin through the join handshake: a crashed member
/// comes back at the same address, re-enters at the next incarnation,
/// and the fleet re-converges on the full union.
#[test]
fn crashed_member_rejoins_and_fleet_reconverges() {
    let mut s = Scenario::default();
    s.name = "rejoin".into();
    s.members = 6;
    s.rounds = 28;
    s.items_per_member = 100;
    s.alpha = 0.01;
    s.max_buckets = 256;
    s.suspect_after_ms = 1_000;
    s.events = vec![
        ScheduledEvent {
            round: 3,
            action: EventAction::Crash(1),
        },
        ScheduledEvent {
            round: 10,
            action: EventAction::Rejoin(1),
        },
    ];

    let report = SimFleet::new(s, 55).unwrap().run().unwrap();
    assert_eq!(report.rounds[2].alive, 5, "crash takes one member down");
    assert_eq!(report.rounds[2].downed, 1);
    assert_eq!(report.rounds[9].alive, 6, "rejoin brings it back");
    assert_eq!(report.rounds[9].downed, 0);
    let converged = report.converged_round.expect("fleet must re-converge");
    assert!(
        converged >= 10,
        "final convergence includes the rejoined stream (round {converged})"
    );
    assert!(report.final_max_rel_err <= report.tol);
}
