//! Integration: the PJRT runtime path (AOT JAX/Pallas artifacts) against
//! the native Rust implementations.
//!
//! Requires `make artifacts` (skipped gracefully when the PJRT plugin or
//! the artifacts are unavailable so `cargo test` works pre-`make`).

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::{ExecutorKind, ExperimentConfig};
use duddsketch::data::{all_peer_datasets, DatasetKind};
use duddsketch::gossip::{
    DenseRound, NativeExecutor, PeerState, PjrtExecutor, Protocol, RoundExecutor, RoundMode,
};
use duddsketch::graph::paper_ba;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::runtime::Runtime;
use duddsketch::sketch::{LogMapping, Store, UddSketch};

fn have_artifacts() -> bool {
    duddsketch::runtime::artifacts_dir()
        .join("avg_pairs_p64_w128.hlo.txt")
        .exists()
}

fn pjrt_or_skip(peers: usize) -> Option<PjrtExecutor> {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match PjrtExecutor::discover(peers) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e:#}");
            None
        }
    }
}

fn mk_states(n: usize, seed: u64) -> Vec<PeerState> {
    let mut r = default_rng(seed);
    (0..n)
        .map(|l| {
            let data: Vec<f64> =
                (0..200).map(|_| 1.0 + 80.0 * r.next_f64()).collect();
            PeerState::init(l, &data, 0.01, 256).unwrap()
        })
        .collect()
}

fn matching(n: usize, seed: u64) -> Vec<usize> {
    let mut r = default_rng(seed);
    let mut partner: Vec<usize> = (0..n).collect();
    let order = r.permutation(n);
    for pair in order.chunks(2) {
        if let [a, b] = *pair {
            partner[a] = b;
            partner[b] = a;
        }
    }
    partner
}

#[test]
fn pjrt_average_matches_native_within_f32() {
    let Some(mut pjrt) = pjrt_or_skip(48) else { return };
    let mut states_a = mk_states(48, 1);
    let mut states_b = states_a.clone();
    let partner = matching(48, 2);

    let mut native_round =
        DenseRound::build(&mut states_a, &partner, pjrt.preferred_width()).unwrap();
    NativeExecutor.average(&mut native_round).unwrap();

    let mut pjrt_round =
        DenseRound::build(&mut states_b, &partner, pjrt.preferred_width()).unwrap();
    pjrt.average(&mut pjrt_round).unwrap();

    assert_eq!(native_round.matrix.len(), pjrt_round.matrix.len());
    for (i, (n, p)) in native_round
        .matrix
        .iter()
        .zip(pjrt_round.matrix.iter())
        .enumerate()
    {
        let tol = 1e-6 * n.abs().max(1.0);
        assert!((n - p).abs() <= tol, "elem {i}: native {n} pjrt {p}");
    }
}

#[test]
fn full_protocol_pjrt_vs_native_matched_mode() {
    if pjrt_or_skip(60).is_none() {
        return;
    }
    let mut cfg = ExperimentConfig::default();
    cfg.peers = 60;
    cfg.items_per_peer = 300;
    cfg.dataset = DatasetKind::Uniform;
    cfg.alpha = 0.01;
    cfg.max_buckets = 128;
    let master = default_rng(cfg.seed);
    let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
    let mut grng = master.derive(0x6EA4);
    let graph = paper_ba(cfg.peers, &mut grng);

    // Native matched-mode reference.
    let mut cfg_native = cfg.clone();
    cfg_native.executor = ExecutorKind::Native;
    let mut native = Protocol::new(&cfg_native, graph.clone(), &datasets, &master).unwrap();
    native.set_mode(RoundMode::Matched);
    native.run(40);

    // PJRT matched mode (same seed -> same matchings).
    let mut cfg_pjrt = cfg.clone();
    cfg_pjrt.executor = ExecutorKind::Pjrt;
    let mut pjrt = Protocol::new(&cfg_pjrt, graph, &datasets, &master).unwrap();
    pjrt.run(40);

    for &q in &[0.01, 0.5, 0.99] {
        for l in 0..cfg.peers {
            let a = native.states()[l].query(q).unwrap();
            let b = pjrt.states()[l].query(q).unwrap();
            let re = (a - b).abs() / a.abs().max(1e-12);
            assert!(re < 1e-3, "peer {l} q={q}: native {a} pjrt {b}");
        }
    }
}

#[test]
fn bucketize_artifact_matches_native_ingest() {
    if !have_artifacts() {
        return;
    }
    let Ok(mut rt) = Runtime::cpu() else { return };
    let exe = match rt.load("bucketize_p4096_w512") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    let mut r = default_rng(3);
    let xs: Vec<f64> = (0..4096).map(|_| 1.0 + 99.0 * r.next_f64()).collect();
    let mapping = LogMapping::new(0.01).unwrap();
    // Native histogram over a window anchored one slot below the min index.
    let offset = xs.iter().map(|&x| mapping.index(x)).min().unwrap() - 1;
    let mut native_hist = vec![0f64; 512];
    for &x in &xs {
        let k = (mapping.index(x) - offset).clamp(0, 511) as usize;
        native_hist[k] += 1.0;
    }

    let xs_f32: Vec<f32> = xs.iter().map(|&x| x as f32).collect();
    let params: Vec<f32> = vec![(1.0 / mapping.gamma().ln()) as f32, offset as f32];
    let out = exe
        .run1(&[xla::Literal::vec1(&xs_f32), xla::Literal::vec1(&params)])
        .unwrap();
    let hist: Vec<f32> = out.to_vec().unwrap();

    assert_eq!(hist.len(), 512);
    let total: f32 = hist.iter().sum();
    assert_eq!(total, 4096.0);
    // f32 log vs f64 log can flip values sitting exactly on a bucket edge;
    // allow a tiny count of edge flips between adjacent buckets.
    let mut moved = 0.0;
    for (k, (&h, &n)) in hist.iter().zip(native_hist.iter()).enumerate() {
        let d = (h as f64 - n).abs();
        if d != 0.0 {
            assert!(d <= 3.0, "slot {k}: pjrt {h} native {n}");
            moved += d;
        }
    }
    assert!(moved <= 16.0, "too many edge flips: {moved}");
}

#[test]
fn collapse_artifact_matches_store_collapse() {
    if !have_artifacts() {
        return;
    }
    let Ok(mut rt) = Runtime::cpu() else { return };
    let exe = match rt.load("collapse_p1_w512") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    for offset in [6i64, 7] {
        // Build a sparse store with indices offset..offset+512.
        let mut store = duddsketch::sketch::SparseStore::empty();
        let mut hist = vec![0f32; 512];
        let mut r = default_rng(4 + offset as u64);
        for k in 0..512i64 {
            let c = r.next_below(5) as f64;
            if c > 0.0 {
                store.add(offset + k, c);
                hist[k as usize] = c as f32;
            }
        }
        store.uniform_collapse();

        let phase = if offset % 2 == 0 { 1.0f32 } else { 0.0 };
        let out = exe
            .run1(&[xla::Literal::vec1(&hist), xla::Literal::vec1(&[phase])])
            .unwrap();
        let collapsed: Vec<f32> = out.to_vec().unwrap();
        assert_eq!(collapsed.len(), 257);
        let out_offset = (offset + 1).div_euclid(2);
        for (j, &c) in collapsed.iter().enumerate() {
            let want = store.get(out_offset + j as i64);
            assert_eq!(
                c as f64, want,
                "offset {offset} slot {j} (index {})",
                out_offset + j as i64
            );
        }
    }
}

#[test]
fn avg_pairs_artifact_handles_padding() {
    // Fewer live peers than the artifact's static P: padded rows must
    // stay untouched and live rows average correctly.
    let Some(mut pjrt) = pjrt_or_skip(10) else { return };
    let mut states = mk_states(10, 5);
    let n_before: Vec<f64> = states.iter().map(|s| s.n_tilde).collect();
    let mut partner: Vec<usize> = (0..10).collect();
    partner[0] = 9;
    partner[9] = 0;
    let mut round =
        DenseRound::build(&mut states, &partner, pjrt.preferred_width()).unwrap();
    pjrt.average(&mut round).unwrap();
    round.write_back(&mut states);
    let avg = 0.5 * (n_before[0] + n_before[9]);
    assert!((states[0].n_tilde - avg).abs() < 1e-3);
    assert!((states[9].n_tilde - avg).abs() < 1e-3);
    for l in 1..9 {
        assert!((states[l].n_tilde - n_before[l]).abs() < 1e-6);
    }
}

#[test]
fn sequential_vs_matched_same_fixed_point() {
    // Mode ablation: both round disciplines converge to the same global
    // sketch (the paper's fixed point) — matched just needs more rounds.
    let mut cfg = ExperimentConfig::default();
    cfg.peers = 50;
    cfg.items_per_peer = 200;
    cfg.dataset = DatasetKind::Exponential;
    let master = default_rng(cfg.seed);
    let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
    let mut seq: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
    for d in &datasets {
        seq.extend(d);
    }
    let mut grng = master.derive(0x6EA4);
    let graph = paper_ba(cfg.peers, &mut grng);

    let mut a = Protocol::new(&cfg, graph.clone(), &datasets, &master).unwrap();
    a.run(30);
    let mut b = Protocol::new(&cfg, graph, &datasets, &master).unwrap();
    b.set_mode(RoundMode::Matched);
    b.run(80);

    for &q in &[0.1, 0.5, 0.9] {
        let truth = seq.quantile(q).unwrap();
        for l in 0..cfg.peers {
            let ea = a.states()[l].query(q).unwrap();
            let eb = b.states()[l].query(q).unwrap();
            assert!((ea - truth).abs() / truth < 1e-6, "seq-mode q={q}");
            assert!((eb - truth).abs() / truth < 1e-6, "matched-mode q={q}");
        }
    }
}
