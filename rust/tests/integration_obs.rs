//! Integration: the node-wide observability plane (ISSUE 6).
//!
//! Acceptance:
//! * a fleet of 4 real nodes gossiping over **loopback TCP under live
//!   ingest** serves `GET /metrics` per node — well-formed Prometheus
//!   text exposition carrying the ingest, gossip, transport, and
//!   membership families, including the UddSketch-backed exchange-RTT
//!   summary;
//! * the scraped `dudd_exchanges_total` equals the sum of
//!   `GossipRoundReport::exchanges` over every round the node ran — the
//!   registry and the report are two views of one set of books;
//! * the endpoint speaks enough HTTP to be scraped by a stock agent:
//!   200 on `GET /metrics`, 404 elsewhere, `Connection: close`.
//!
//! ISSUE 10 extends the fleet with per-node JSONL event logs and the
//! convergence observatory: a traced exchange's 64-bit id must appear
//! in **both** the initiator's and the server's log with consistent
//! kind/bytes/generation, and `observe_fleet` must reassemble the
//! fleet from the live endpoints and report convergence with the worst
//! drift inside the scraped Theorem 2 bound
//! (`dudd_union_rel_err_bound`).

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::ServiceConfig;
use duddsketch::data::{peer_dataset, DatasetKind};
use duddsketch::prelude::*;
use duddsketch::rng::default_rng;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn service_cfg() -> ServiceConfig {
    let mut c = ServiceConfig::default();
    c.shards = 2;
    c.batch_size = 256;
    c.gossip.round_interval_ms = 0; // tests are the clock
    c
}

/// Bind `n` transports first (address book before any loop starts), then
/// build the fleet with an ephemeral `/metrics` listener per node.
fn observed_tcp_fleet(n: usize, cfg: &ServiceConfig) -> Vec<Node> {
    observed_tcp_fleet_with_logs(n, cfg, &[])
}

/// Same construction, plus a JSONL event log per node (when `logs`
/// names one): with a sink installed both the initiator-side and the
/// serve-side exchange spans land in the node's file, keyed by the
/// wire trace id.
fn observed_tcp_fleet_with_logs(n: usize, cfg: &ServiceConfig, logs: &[PathBuf]) -> Vec<Node> {
    let opts = TcpTransportOptions::from_gossip(&cfg.gossip);
    let transports: Vec<Arc<TcpTransport>> = (0..n)
        .map(|_| Arc::new(TcpTransport::bind_with("127.0.0.1:0", opts.clone()).unwrap()))
        .collect();
    let addrs: Vec<SocketAddr> = transports
        .iter()
        .map(|t| t.listen_addr().unwrap())
        .collect();
    transports
        .iter()
        .enumerate()
        .map(|(k, t)| {
            let mut b = Node::builder()
                .config(cfg.clone())
                .self_index(k)
                .transport_shared(t.clone())
                .metrics_bind("127.0.0.1:0".parse().unwrap());
            if let Some(p) = logs.get(k) {
                b = b.event_log(p.clone());
            }
            for (j, &addr) in addrs.iter().enumerate() {
                if j != k {
                    b = b.remote_peer(addr);
                }
            }
            b.build().unwrap()
        })
        .collect()
}

/// One HTTP request against a node's metrics listener; returns
/// (status line, body).
fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_millis(2_000))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf
        .split_once("\r\n\r\n")
        .expect("HTTP response has a header block");
    let status = head.lines().next().unwrap_or_default().to_string();
    (status, body.to_string())
}

fn scrape(addr: SocketAddr) -> String {
    let (status, body) = http_get(addr, "/metrics");
    assert!(status.starts_with("HTTP/1.1 200"), "{status}");
    body
}

/// The value of an **unlabelled** sample line (`<name> <value>`). Exact
/// name match — `dudd_exchanges_total` does not match the `_failed_`
/// family or a `{quantile=...}` summary line.
fn sample(body: &str, name: &str) -> f64 {
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ') {
                return v.trim().parse().unwrap();
            }
        }
    }
    panic!("metric {name} not found in exposition:\n{body}");
}

/// Every non-comment line must be `name[{labels}] value` with a numeric
/// (or NaN) value — the shape a stock Prometheus scraper parses.
fn assert_well_formed(body: &str) {
    assert!(!body.is_empty(), "empty exposition");
    for line in body.lines() {
        if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
            continue;
        }
        let (name, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("sample line without a value: {line:?}"));
        assert!(
            !name.is_empty()
                && name.starts_with(|c: char| c.is_ascii_alphabetic() || c == '_'),
            "bad metric name in line {line:?}"
        );
        assert!(
            value.parse::<f64>().is_ok(),
            "non-numeric sample value in line {line:?}"
        );
    }
}

/// The acceptance test: four real nodes on loopback TCP under live
/// ingest, each serving its own registry; the scraped books must agree
/// with the per-round reports the test itself collected.
#[test]
fn four_tcp_nodes_serve_metrics_matching_their_round_reports() {
    let nodes = 4;
    let items = 2_000;
    let master = default_rng(42);
    let datasets: Vec<Vec<f64>> = (0..nodes)
        .map(|i| peer_dataset(DatasetKind::Exponential, i, items, &master))
        .collect();

    let cfg = service_cfg();
    let fleet = observed_tcp_fleet(nodes, &cfg);
    let metrics_addrs: Vec<SocketAddr> = fleet
        .iter()
        .enumerate()
        .map(|(k, n)| {
            n.metrics_addr()
                .unwrap_or_else(|| panic!("node {k} must bind a /metrics listener"))
        })
        .collect();

    // Live ingest interleaved with gossip sweeps; the test keeps its own
    // tally of every round report per node.
    let mut reported_exchanges = vec![0usize; nodes];
    let mut writers: Vec<_> = fleet.iter().map(|n| n.writer()).collect();
    for step in 0..2 {
        for (k, node) in fleet.iter().enumerate() {
            writers[k].insert_batch(&datasets[k][step * 1_000..(step + 1) * 1_000]);
            writers[k].flush();
            node.flush();
        }
        for (k, node) in fleet.iter().enumerate() {
            let r = node.step().expect("gossip enabled");
            reported_exchanges[k] += r.exchanges;
        }
    }
    drop(writers);
    for _ in 0..30 {
        for (k, node) in fleet.iter().enumerate() {
            let r = node.step().expect("gossip enabled");
            reported_exchanges[k] += r.exchanges;
        }
    }

    for (k, node) in fleet.iter().enumerate() {
        let body = scrape(metrics_addrs[k]);
        assert_well_formed(&body);

        // All four families are present: ingest, gossip, transport,
        // membership.
        for family in [
            "dudd_ingest_values_total",
            "dudd_epochs_total",
            "dudd_rounds_total",
            "dudd_exchanges_total",
            "dudd_wire_bytes_total",
            "dudd_pool_fresh_connects_total",
            "dudd_members_alive",
        ] {
            assert!(
                body.contains(&format!("# TYPE {family} ")),
                "node {k} exposition lacks {family}:\n{body}"
            );
        }

        // The registry's exchange counter and the round reports are two
        // views of the same books.
        assert!(reported_exchanges[k] > 0, "node {k} never exchanged");
        assert_eq!(
            sample(&body, "dudd_exchanges_total") as usize,
            reported_exchanges[k],
            "node {k}: scraped exchanges != summed GossipRoundReport::exchanges"
        );
        assert_eq!(
            sample(&body, "dudd_rounds_total") as usize,
            32,
            "node {k}: one rounds tick per step()"
        );
        assert_eq!(
            sample(&body, "dudd_ingest_values_total") as usize,
            items,
            "node {k}: every inserted value booked"
        );

        // The UddSketch-backed exchange-RTT summary carries real
        // observations: one per completed initiator-side exchange.
        assert!(
            body.contains("dudd_exchange_rtt_seconds{quantile=\"0.99\"}"),
            "node {k} lacks RTT quantile samples:\n{body}"
        );
        let rtt_count = sample(&body, "dudd_exchange_rtt_seconds_count");
        assert!(
            rtt_count > 0.0,
            "node {k}: RTT summary never observed an exchange"
        );
        assert!(
            sample(&body, "dudd_exchange_rtt_seconds_sum") >= 0.0,
            "node {k}: RTT sum must be non-negative"
        );

        // Transport wire accounting reached the registry.
        assert!(
            sample(&body, "dudd_wire_bytes_total") > 0.0,
            "node {k}: no wire bytes booked"
        );

        // The same numbers are visible in-process without a scrape.
        let m = node.metrics();
        assert_eq!(m.gossip.exchanges.get() as usize, reported_exchanges[k]);
        assert_eq!(m.service.values.get() as usize, items);
    }

    for node in fleet {
        node.shutdown();
    }
}

/// The listener speaks enough HTTP for a stock scraper: 404 off-path,
/// and a second scrape sees counters move monotonically.
#[test]
fn metrics_endpoint_serves_404_off_path_and_monotone_counters() {
    let node = Node::builder()
        .config(service_cfg())
        .shards(1)
        .metrics_bind("127.0.0.1:0".parse().unwrap())
        .build()
        .unwrap();
    let addr = node.metrics_addr().expect("listener bound");

    let (status, _) = http_get(addr, "/definitely-not-metrics");
    assert!(status.starts_with("HTTP/1.1 404"), "{status}");

    let mut w = node.writer();
    w.insert_batch(&[1.0, 2.0]);
    w.flush();
    node.flush();
    let first = sample(&scrape(addr), "dudd_ingest_values_total");
    assert_eq!(first, 2.0);

    w.insert_batch(&[3.0, 4.0, 5.0]);
    w.flush();
    node.flush();
    let second = sample(&scrape(addr), "dudd_ingest_values_total");
    assert_eq!(second, 5.0, "counters are monotone across scrapes");

    drop(w);
    node.shutdown();
}

/// ISSUE 10 E2E: four TCP nodes with JSONL event logs. A traced
/// exchange's id must appear in both ends' logs with consistent
/// kind/bytes/generation, and the observatory must reassemble the
/// fleet from the live endpoints: `verdict == "converged"` with
/// `max_drift` inside the scraped `dudd_union_rel_err_bound` gauge —
/// the Theorem 2 check, measured rather than assumed.
#[test]
fn traced_exchange_ids_join_across_logs_and_observatory_sees_convergence() {
    use duddsketch::obs::observe::{join_event_logs, observe_fleet};

    let nodes = 4;
    let items = 2_000;
    let master = default_rng(7);
    let datasets: Vec<Vec<f64>> = (0..nodes)
        .map(|i| peer_dataset(DatasetKind::Exponential, i, items, &master))
        .collect();

    let dir = std::env::temp_dir().join(format!("dudd-obs-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let logs: Vec<PathBuf> = (0..nodes)
        .map(|k| dir.join(format!("node{k}.jsonl")))
        .collect();
    for p in &logs {
        let _ = std::fs::remove_file(p); // stale files from a previous run
    }

    let cfg = service_cfg();
    let fleet = observed_tcp_fleet_with_logs(nodes, &cfg, &logs);
    let metrics_addrs: Vec<SocketAddr> = fleet
        .iter()
        .enumerate()
        .map(|(k, n)| {
            n.metrics_addr()
                .unwrap_or_else(|| panic!("node {k} must bind a /metrics listener"))
        })
        .collect();

    // Live ingest interleaved with gossip sweeps, then drain until
    // every node's round report says converged (bounded — the static
    // 4-node fleet gets there in a handful of sweeps).
    let mut writers: Vec<_> = fleet.iter().map(|n| n.writer()).collect();
    for step in 0..2 {
        for (k, node) in fleet.iter().enumerate() {
            writers[k].insert_batch(&datasets[k][step * 1_000..(step + 1) * 1_000]);
            writers[k].flush();
            node.flush();
        }
        for node in &fleet {
            node.step().expect("gossip enabled");
        }
    }
    drop(writers);
    let mut drained = false;
    for _ in 0..100 {
        let mut sweep_converged = true;
        for node in &fleet {
            let r = node.step().expect("gossip enabled");
            sweep_converged &= r.converged;
        }
        if sweep_converged {
            drained = true;
            break;
        }
    }
    assert!(drained, "fleet never converged under drain sweeps");

    // The observatory over the live endpoints. A generation catch-up
    // can trail the drift settling by a sweep or two, so allow a few
    // extra bounded sweeps before pinning the verdict.
    let targets: Vec<String> = metrics_addrs.iter().map(|a| a.to_string()).collect();
    let mut report = observe_fleet(&targets, Duration::from_secs(2));
    for _ in 0..20 {
        if report.verdict == "converged" {
            break;
        }
        for node in &fleet {
            node.step().expect("gossip enabled");
        }
        report = observe_fleet(&targets, Duration::from_secs(2));
    }
    assert!(
        report.unreachable.is_empty(),
        "unreachable nodes: {:?}",
        report.unreachable
    );
    assert_eq!(report.nodes.len(), nodes, "every endpoint observed");
    assert!(report.generations_agree, "generation split after drain");
    assert!(report.all_converged, "a node still reports converged = 0");
    assert!(
        report.bound.is_finite() && report.bound > 0.0,
        "Theorem 2 bound gauge must be live, got {}",
        report.bound
    );
    assert!(
        report.max_drift <= report.bound,
        "max_rel_err {} exceeds the scraped Theorem 2 bound {}",
        report.max_drift,
        report.bound
    );
    assert_eq!(report.verdict, "converged");
    let json = report.render_json();
    assert!(json.contains("\"verdict\":\"converged\""), "{json}");

    // Hot-path contract: the bounded sink never dropped a line under
    // this load.
    for (k, node) in fleet.iter().enumerate() {
        assert_eq!(
            node.metrics().gossip.events_dropped.get(),
            0,
            "node {k} dropped event-log lines"
        );
    }

    // Shut the fleet down: dropping a node joins its event-log writer,
    // so the files below are complete before they are read.
    for node in fleet {
        node.shutdown();
    }

    let paths: Vec<&std::path::Path> = logs.iter().map(|p| p.as_path()).collect();
    let causal = join_event_logs(&paths).expect("read the per-node JSONL logs");
    assert!(!causal.is_empty(), "no traced exchanges in the logs");
    let paired: Vec<_> = causal.iter().filter(|c| c.consistent()).collect();
    assert!(
        !paired.is_empty(),
        "no trace id joined across two nodes' logs"
    );
    for c in &paired {
        let (i, s) = (c.initiator.as_ref().unwrap(), c.server.as_ref().unwrap());
        assert_eq!(i.kind, s.kind, "trace {}: frame kind", c.trace_id);
        assert_eq!(
            i.generation, s.generation,
            "trace {}: restart generation",
            c.trace_id
        );
        assert_ne!(i.node, s.node, "trace {}: two distinct nodes", c.trace_id);
        if i.outcome == "ok" && s.outcome == "ok" {
            assert_eq!(
                i.bytes, s.bytes,
                "trace {}: both ends count push + reply bytes",
                c.trace_id
            );
        }
    }
    assert!(
        paired.iter().any(|c| {
            let (i, s) = (c.initiator.as_ref().unwrap(), c.server.as_ref().unwrap());
            i.outcome == "ok" && s.outcome == "ok" && i.bytes == s.bytes
        }),
        "no ok/ok causal pair with matching byte counts"
    );

    for p in &logs {
        let _ = std::fs::remove_file(p);
    }
    let _ = std::fs::remove_dir(&dir);
}
