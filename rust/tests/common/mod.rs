//! Shared helpers for the integration suites.
//!
//! The point of this module is de-flaking: wall-clock assertions poll a
//! condition under a **bounded deadline** instead of sleeping a fixed
//! interval and asserting once. A fixed sleep is always wrong twice —
//! too short on a loaded CI machine (flake) and too long everywhere
//! else (wasted wall time). Polling exits the moment the condition
//! holds and only pays the full deadline on an actual failure.
//!
//! Each suite pulls this in with `mod common;`; helpers unused by a
//! given test binary are expected.
#![allow(dead_code)]

use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// Pause between condition probes. Short enough that a satisfied
/// condition is observed almost immediately; long enough that a tight
/// poll loop cannot starve the threads it is waiting on.
pub const TICK: Duration = Duration::from_millis(10);

/// Poll `cond` every [`TICK`] until it holds or `deadline` elapses.
///
/// Returns `Some(probes)` — how many times `cond` ran — when the
/// condition held, `None` on timeout. The condition is always probed at
/// least once, so a zero deadline degrades to a single check.
pub fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> Option<usize> {
    let start = Instant::now();
    let mut probes = 0usize;
    loop {
        probes += 1;
        if cond() {
            return Some(probes);
        }
        if start.elapsed() >= deadline {
            return None;
        }
        std::thread::sleep(TICK);
    }
}

/// [`wait_until`], panicking with `what` on timeout. Use when there is
/// no richer diagnostic to attach than the condition's name.
pub fn wait_for(what: &str, deadline: Duration, cond: impl FnMut() -> bool) -> usize {
    match wait_until(deadline, cond) {
        Some(probes) => probes,
        None => panic!("timed out after {deadline:?} waiting for {what}"),
    }
}

/// Bounded wait until a TCP connect to `addr` succeeds — i.e. the
/// remote listener is up and accepting. The probe connections are
/// dropped immediately; servers must tolerate a connection that closes
/// without sending a frame (the codec treats it as a truncated read).
pub fn wait_tcp_ready(addr: SocketAddr, deadline: Duration) {
    wait_for(&format!("listener at {addr}"), deadline, || {
        TcpStream::connect_timeout(&addr, TICK.max(Duration::from_millis(50))).is_ok()
    });
}
