//! Integration: the full distributed protocol across modules — datasets,
//! overlays, churn models, and the experiment runner.

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::churn::ChurnKind;
use duddsketch::config::{ExperimentConfig, GraphKind};
use duddsketch::data::{all_peer_datasets, DatasetKind};
use duddsketch::experiments::run_with_snapshots;
use duddsketch::gossip::Protocol;
use duddsketch::graph::{paper_ba, paper_er};
use duddsketch::metrics::relative_error;
use duddsketch::rng::default_rng;
use duddsketch::sketch::UddSketch;

fn cfg_with(dataset: DatasetKind, peers: usize, items: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = dataset;
    cfg.peers = peers;
    cfg.items_per_peer = items;
    cfg
}

fn sequential_reference(cfg: &ExperimentConfig, datasets: &[Vec<f64>]) -> UddSketch {
    let mut seq: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets).unwrap();
    for d in datasets {
        seq.extend(d);
    }
    seq
}

/// Convergence across all four synthetic workloads (the §7.1 suite in
/// miniature): 25 rounds drive every peer's answer to the sequential one.
#[test]
fn all_synthetic_datasets_converge() {
    for dataset in DatasetKind::SYNTHETIC {
        let cfg = cfg_with(dataset, 120, 300);
        let master = default_rng(11);
        let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
        let seq = sequential_reference(&cfg, &datasets);
        let mut grng = master.derive(0x6EA4);
        let graph = paper_ba(cfg.peers, &mut grng);
        let mut proto = Protocol::new(&cfg, graph, &datasets, &master).unwrap();
        proto.run(35);
        for &q in &[0.01, 0.5, 0.99] {
            let truth = seq.quantile(q).unwrap();
            let worst = (0..cfg.peers)
                .map(|l| relative_error(proto.states()[l].query(q).unwrap(), truth))
                .fold(0.0f64, f64::max);
            assert!(
                worst < 1e-4,
                "{dataset:?} q={q}: worst per-peer RE {worst}"
            );
        }
    }
}

/// §7: "no appreciable differences between the two random graph models".
#[test]
fn er_and_ba_overlays_agree_at_convergence() {
    let cfg = cfg_with(DatasetKind::Exponential, 100, 300);
    let master = default_rng(12);
    let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
    let seq = sequential_reference(&cfg, &datasets);
    let mut grng = master.derive(0x6EA4);
    let ba = paper_ba(cfg.peers, &mut grng);
    let er = paper_er(cfg.peers, &mut grng);
    let mut pa = Protocol::new(&cfg, ba, &datasets, &master).unwrap();
    let mut pe = Protocol::new(&cfg, er, &datasets, &master).unwrap();
    pa.run(30);
    pe.run(30);
    let truth = seq.quantile(0.5).unwrap();
    for l in 0..cfg.peers {
        assert!(relative_error(pa.states()[l].query(0.5).unwrap(), truth) < 1e-6);
        assert!(relative_error(pe.states()[l].query(0.5).unwrap(), truth) < 1e-6);
    }
}

/// Yao churn slows convergence but does not prevent it (§7.2, Figs 7–10).
#[test]
fn yao_churn_converges_eventually() {
    for churn in [ChurnKind::YaoPareto, ChurnKind::YaoExponential] {
        let mut cfg = cfg_with(DatasetKind::Uniform, 100, 200);
        cfg.churn = churn;
        let out = run_with_snapshots(&cfg, &[5, 60]).unwrap();
        let early: f64 = out.snapshots[0].quantiles.iter().map(|q| q.are).sum();
        let late: f64 = out.snapshots[1].quantiles.iter().map(|q| q.are).sum();
        assert!(
            late < early || late < 1e-6,
            "{churn:?}: ARE grew {early} -> {late}"
        );
        assert!(late < 0.05, "{churn:?}: late total ARE {late}");
    }
}

/// Fail&Stop can disconnect the overlay; errors then stall above zero on
/// the adversarial input (the paper's Fig. 5 observation).
#[test]
fn failstop_on_adversarial_stalls_above_zero() {
    let mut cfg = cfg_with(DatasetKind::Adversarial, 300, 150);
    cfg.churn = ChurnKind::FailStop;
    cfg.seed = 13;
    let out = run_with_snapshots(&cfg, &[60]).unwrap();
    let snap = &out.snapshots[0];
    assert!(
        snap.online < 300,
        "fail&stop must have killed peers ({} online)",
        snap.online
    );
    // Not asserting non-convergence (depends on where failures landed) —
    // but the run must complete and report finite errors.
    for qs in &snap.quantiles {
        assert!(qs.are.is_finite());
    }
}

/// The protocol's network-size estimator is itself correct: p̃ -> p.
#[test]
fn network_size_estimation_converges() {
    let cfg = cfg_with(DatasetKind::Exponential, 77, 100);
    let master = default_rng(14);
    let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
    let mut grng = master.derive(0x6EA4);
    let graph = paper_ba(cfg.peers, &mut grng);
    let mut proto = Protocol::new(&cfg, graph, &datasets, &master).unwrap();
    proto.run(40);
    for s in proto.states() {
        assert_eq!(s.estimated_peers(), 77.0, "peer {}", s.id);
        assert_eq!(s.estimated_total(), 7700.0, "peer {}", s.id);
    }
}

/// Fan-out > 1 accelerates convergence (§4: "our approach gives each peer
/// the option to gossip with a user-defined number of neighbours").
#[test]
fn higher_fanout_converges_faster() {
    let run_with_fanout = |fan_out: usize| -> f64 {
        let mut cfg = cfg_with(DatasetKind::Adversarial, 200, 150);
        cfg.fan_out = fan_out;
        cfg.seed = 15;
        let out = run_with_snapshots(&cfg, &[4]).unwrap();
        out.snapshots[0].quantiles.iter().map(|q| q.are).sum()
    };
    let are1 = run_with_fanout(1);
    let are4 = run_with_fanout(4);
    assert!(
        are4 < are1,
        "fan-out 4 should beat fan-out 1 at round 4: {are4} vs {are1}"
    );
}

/// Exchange-failure injection (§7.2 cancel/restore semantics) never breaks
/// correctness, only speed: with 30% of exchanges cancelled the protocol
/// still converges.
#[test]
fn exchange_failures_only_slow_convergence() {
    let cfg = cfg_with(DatasetKind::Uniform, 80, 200);
    let master = default_rng(16);
    let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
    let seq = sequential_reference(&cfg, &datasets);
    let mut grng = master.derive(0x6EA4);
    let graph = paper_ba(cfg.peers, &mut grng);
    let mut proto = Protocol::new(&cfg, graph, &datasets, &master).unwrap();
    proto.set_exchange_drop(0.3);
    proto.run(45);
    let truth = seq.quantile(0.9).unwrap();
    for l in 0..cfg.peers {
        let re = relative_error(proto.states()[l].query(0.9).unwrap(), truth);
        assert!(re < 1e-4, "peer {l}: {re}");
    }
}

/// Mergeability at the system level: running the distributed protocol on
/// disjoint halves of a stream and merging any two converged peers' local
/// sketches answers for the union.
#[test]
fn converged_peer_states_are_reusable_summaries() {
    let cfg = cfg_with(DatasetKind::Power, 64, 250);
    let master = default_rng(17);
    let datasets = all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
    let seq = sequential_reference(&cfg, &datasets);
    let mut grng = master.derive(0x6EA4);
    let graph = paper_ba(cfg.peers, &mut grng);
    let mut proto = Protocol::new(&cfg, graph, &datasets, &master).unwrap();
    proto.run(30);
    // All peers answer identically (consensus) and match the sequential
    // reference.
    let answers: Vec<f64> = (0..cfg.peers)
        .map(|l| proto.states()[l].query(0.95).unwrap())
        .collect();
    let first = answers[0];
    assert!(answers.iter().all(|&a| (a - first).abs() < 1e-9 * first));
    let truth = seq.quantile(0.95).unwrap();
    assert!(relative_error(first, truth) < 1e-6);
}
