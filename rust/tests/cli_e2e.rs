//! End-to-end CLI tests: drive the actual `duddsketch` binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_duddsketch"))
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("figure"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = bin().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn run_small_experiment() {
    let out = bin()
        .args([
            "run",
            "peers=40",
            "items=100",
            "rounds=10",
            "dataset=uniform",
            "quantiles=0.5,0.99",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("ARE"), "{text}");
    assert!(text.contains("rounds=10"), "{text}");
}

#[test]
fn figure_list_and_table2() {
    let out = bin().args(["figure", "--list"]).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("fig11"));

    let out = bin().args(["figure", "--id", "table2"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("alpha"), "{text}");
    assert!(text.contains("1024"), "{text}");
}

#[test]
fn quantiles_subcommand_generated_data() {
    let out = bin()
        .args([
            "quantiles",
            "--dataset",
            "exponential",
            "--items",
            "5000",
            "--q",
            "0.5,0.95",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("n=5000"), "{text}");
    assert!(text.contains("q=0.95"), "{text}");
}

#[test]
fn serve_bench_runs_end_to_end() {
    let out = bin()
        .args([
            "serve-bench",
            "--dataset",
            "exponential",
            "--items",
            "20000",
            "--shards",
            "2",
            "batch=512",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("serve-bench: dataset=exponential"), "{text}");
    assert!(text.contains("worst-rel-diff"), "{text}");
}

#[test]
fn serve_gossip_runs_end_to_end() {
    let out = bin()
        .args([
            "serve-gossip",
            "--dataset",
            "uniform",
            "--items",
            "2000",
            "--nodes",
            "3",
            "--rounds",
            "10",
            "batch=256",
            "shards=2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("serve-gossip: dataset=uniform"), "{text}");
    assert!(text.contains("OK: worst rel-diff"), "{text}");
}

#[test]
fn info_reports_defaults() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("artifacts dir"), "{text}");
    assert!(text.contains("defaults"), "{text}");
}

#[test]
fn invalid_config_value_is_rejected() {
    let out = bin().args(["run", "alpha=2.0"]).output().unwrap();
    assert!(!out.status.success());
}
