"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and values; every property asserts allclose
between the interpret-mode Pallas kernel and its ref.py twin.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.avg_pairs import avg_pairs
from compile.kernels.bucketize import BLOCK, bucketize
from compile.kernels.collapse import collapse
from compile.kernels.ref import ref_avg_pairs, ref_bucketize, ref_collapse

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def random_involution(rng, p):
    """A random partner vector: involution with idle fixed points."""
    partner = np.arange(p, dtype=np.int32)
    order = rng.permutation(p)
    for a, b in zip(order[0::2], order[1::2]):
        if rng.random() < 0.8:  # leave some peers idle
            partner[a] = b
            partner[b] = a
    return partner


# ---------------------------------------------------------------------------
# avg_pairs
# ---------------------------------------------------------------------------


@given(
    p=st.integers(min_value=2, max_value=48),
    c=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_avg_pairs_matches_ref(p, c, seed):
    rng = np.random.default_rng(seed)
    states = jnp.asarray(rng.normal(size=(p, c)).astype(np.float32))
    partner = jnp.asarray(random_involution(rng, p))
    got = avg_pairs(states, partner)
    want = ref_avg_pairs(states, partner)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_avg_pairs_conserves_column_sums(seed):
    rng = np.random.default_rng(seed)
    p, c = 32, 10
    states = jnp.asarray(rng.uniform(0, 100, size=(p, c)).astype(np.float32))
    partner = jnp.asarray(random_involution(rng, p))
    out = np.asarray(avg_pairs(states, partner))
    np.testing.assert_allclose(
        out.sum(axis=0), np.asarray(states).sum(axis=0), rtol=1e-5
    )


def test_avg_pairs_identity_when_all_idle():
    states = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    partner = jnp.arange(4, dtype=jnp.int32)
    out = avg_pairs(states, partner)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(states))


def test_avg_pairs_pairs_become_identical():
    states = jnp.asarray([[0.0, 2.0], [4.0, 6.0], [1.0, 1.0]], dtype=jnp.float32)
    partner = jnp.asarray([1, 0, 2], dtype=jnp.int32)
    out = np.asarray(avg_pairs(states, partner))
    np.testing.assert_array_equal(out[0], out[1])
    np.testing.assert_array_equal(out[0], [2.0, 4.0])
    np.testing.assert_array_equal(out[2], [1.0, 1.0])


# ---------------------------------------------------------------------------
# bucketize
# ---------------------------------------------------------------------------


@given(
    blocks=st.integers(min_value=1, max_value=3),
    width=st.integers(min_value=8, max_value=256),
    lo_exp=st.floats(min_value=-3.0, max_value=2.0),
    decades=st.floats(min_value=0.5, max_value=6.0),
    alpha=st.sampled_from([0.001, 0.01, 0.05]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_bucketize_matches_ref(blocks, width, lo_exp, decades, alpha, seed):
    rng = np.random.default_rng(seed)
    b = blocks * BLOCK
    xs = 10.0 ** rng.uniform(lo_exp, lo_exp + decades, size=b)
    xs = jnp.asarray(xs.astype(np.float32))
    gamma = (1 + alpha) / (1 - alpha)
    inv_ln_gamma = 1.0 / math.log(gamma)
    # Window anchored at the data's min index.
    offset = math.ceil(math.log(float(xs.min())) * inv_ln_gamma) - 1
    params = jnp.asarray([inv_ln_gamma, float(offset)], dtype=jnp.float32)
    got = bucketize(xs, params, width=width)
    want = ref_bucketize(xs, params, width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bucketize_total_equals_batch():
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.uniform(1.0, 100.0, size=2 * BLOCK).astype(np.float32))
    params = jnp.asarray([1.0 / math.log(1.02), 0.0], dtype=jnp.float32)
    hist = np.asarray(bucketize(xs, params, width=512))
    assert hist.sum() == 2 * BLOCK


def test_bucketize_rejects_ragged_batch():
    xs = jnp.ones(BLOCK + 1, dtype=jnp.float32)
    params = jnp.asarray([1.0, 0.0], dtype=jnp.float32)
    with pytest.raises(AssertionError):
        bucketize(xs, params, width=16)


def test_bucketize_clamps_out_of_window():
    # Values far below/above the window end up in the edge slots.
    xs = np.full(BLOCK, 1e-30, dtype=np.float32)
    xs[: BLOCK // 2] = 1e30
    params = jnp.asarray([1.0 / math.log(1.02), 0.0], dtype=jnp.float32)
    hist = np.asarray(bucketize(jnp.asarray(xs), params, width=64))
    assert hist[0] == BLOCK // 2
    assert hist[-1] == BLOCK // 2
    assert hist[1:-1].sum() == 0


# ---------------------------------------------------------------------------
# collapse
# ---------------------------------------------------------------------------


@given(
    half=st.integers(min_value=2, max_value=128),
    phase=st.sampled_from([0.0, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_collapse_matches_ref(half, phase, seed):
    rng = np.random.default_rng(seed)
    w = 2 * half
    hist = jnp.asarray(rng.integers(0, 50, size=w).astype(np.float32))
    ph = jnp.asarray([phase], dtype=jnp.float32)
    got = collapse(hist, ph)
    want = ref_collapse(hist, ph)
    assert got.shape == (w // 2 + 1,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    half=st.integers(min_value=2, max_value=64),
    phase=st.sampled_from([0.0, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_collapse_conserves_mass(half, phase, seed):
    rng = np.random.default_rng(seed)
    hist = jnp.asarray(rng.uniform(0, 9, size=2 * half).astype(np.float32))
    out = np.asarray(collapse(hist, jnp.asarray([phase], dtype=jnp.float32)))
    np.testing.assert_allclose(out.sum(), np.asarray(hist).sum(), rtol=1e-6)


def test_collapse_matches_sketch_semantics():
    # Window offset o=1 (odd -> phase 0): indices 1..8 with counter == index.
    # ceil pairing: (1,2)->1, (3,4)->2, (5,6)->3, (7,8)->4 — the same case
    # the Rust store test exercises.
    hist = jnp.asarray([1, 2, 3, 4, 5, 6, 7, 8], dtype=jnp.float32)
    out = np.asarray(collapse(hist, jnp.asarray([0.0], dtype=jnp.float32)))
    np.testing.assert_array_equal(out, [3.0, 7.0, 11.0, 15.0, 0.0])
    # Offset o=2 (even -> phase 1): indices 2..9.
    # (2)->1, (3,4)->2, (5,6)->3, (7,8)->4, (9)->5.
    out = np.asarray(collapse(hist, jnp.asarray([1.0], dtype=jnp.float32)))
    np.testing.assert_array_equal(out, [1.0, 5.0, 9.0, 13.0, 8.0])
