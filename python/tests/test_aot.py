"""AOT path: HLO text emission round-trips through the XLA text parser."""

import pathlib

import pytest

from compile import aot, model


def test_to_hlo_text_contains_module():
    lowered = model.lower_gossip_round(8, 6)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule") or "HloModule" in text
    # return_tuple=True: root is a tuple.
    assert "tuple" in text


def test_emit_writes_all_artifacts(tmp_path: pathlib.Path):
    # Shrink the shape ladders so the test stays fast.
    old_avg, old_bkt, old_col = (
        aot.AVG_PAIRS_SHAPES,
        aot.BUCKETIZE_SHAPES,
        aot.COLLAPSE_WIDTHS,
    )
    aot.AVG_PAIRS_SHAPES = [(8, 16)]
    aot.BUCKETIZE_SHAPES = [(1024, 32)]
    aot.COLLAPSE_WIDTHS = [16]
    try:
        written = aot.emit(tmp_path)
    finally:
        aot.AVG_PAIRS_SHAPES = old_avg
        aot.BUCKETIZE_SHAPES = old_bkt
        aot.COLLAPSE_WIDTHS = old_col
    names = sorted(p.name for p in written)
    assert names == [
        "avg_pairs_p8_w16.hlo.txt",
        "bucketize_p1024_w32.hlo.txt",
        "collapse_p1_w16.hlo.txt",
    ]
    for p in written:
        assert p.stat().st_size > 100


@pytest.mark.parametrize("p,w", [(8, 16)])
def test_artifact_text_parses_back(p, w, tmp_path):
    """The HLO text must be parseable by XLA's text parser (the exact
    entry point the Rust runtime uses)."""
    from jax._src.lib import xla_client as xc

    lowered = model.lower_gossip_round(p, w + 2)
    text = aot.to_hlo_text(lowered)
    # xla_client exposes the same HLO-text parser the xla crate binds.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
