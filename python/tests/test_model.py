"""Layer-2 model: shapes, lowering, and end-to-end averaging semantics."""

import jax.numpy as jnp
import numpy as np

from compile import model


def test_gossip_round_shape_and_fixed_point():
    p, cols = 8, 6
    states = jnp.asarray(np.random.default_rng(1).uniform(size=(p, cols)),
                         dtype=jnp.float32)
    partner = jnp.arange(p, dtype=jnp.int32)
    out = model.gossip_round(states, partner)
    assert out.shape == (p, cols)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(states))


def test_gossip_round_converges_to_mean():
    # Repeated random matchings drive every row to the global mean — the
    # distributed-averaging fixed point the protocol relies on.
    rng = np.random.default_rng(2)
    p, cols = 16, 4
    states = jnp.asarray(rng.uniform(0, 10, size=(p, cols)), dtype=jnp.float32)
    target = np.asarray(states).mean(axis=0)
    for _ in range(200):
        partner = np.arange(p, dtype=np.int32)
        order = rng.permutation(p)
        for a, b in zip(order[0::2], order[1::2]):
            partner[a] = b
            partner[b] = a
        states = model.gossip_round(states, jnp.asarray(partner))
    np.testing.assert_allclose(np.asarray(states), np.tile(target, (p, 1)),
                               rtol=1e-3)


def test_ingest_counts_and_window():
    xs = jnp.asarray(np.linspace(1.0, 99.0, 1024), dtype=jnp.float32)
    import math
    alpha = 0.01
    gamma = (1 + alpha) / (1 - alpha)
    params = jnp.asarray([1.0 / math.log(gamma), 0.0], dtype=jnp.float32)
    hist = model.ingest(xs, params, width=512)
    assert hist.shape == (512,)
    assert float(hist.sum()) == 1024.0


def test_lowering_produces_stablehlo():
    lowered = model.lower_gossip_round(8, 10)
    text = str(lowered.compiler_ir("stablehlo"))
    assert "func" in text
    lowered = model.lower_ingest(1024, 64)
    assert "func" in str(lowered.compiler_ir("stablehlo"))
    lowered = model.lower_collapse(64)
    assert "func" in str(lowered.compiler_ir("stablehlo"))
