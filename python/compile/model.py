"""Layer 2 — the JAX compute graph the Rust coordinator executes via PJRT.

Three entry points, each a thin jitted wrapper over the corresponding
Layer-1 Pallas kernel so that lowering produces one fused HLO module per
artifact:

* :func:`gossip_round` — the per-round hot path (dense distributed
  averaging, Algorithm 4/5 in matrix form).
* :func:`ingest` — bulk stream ingestion (bucketize + histogram).
* :func:`collapse_step` — uniform collapse on a dense window.

Build-time only: nothing in this package is imported at runtime; the AOT
artifacts produced by :mod:`compile.aot` are the runtime interface.
"""

import jax

from compile.kernels.avg_pairs import avg_pairs
from compile.kernels.bucketize import bucketize
from compile.kernels.collapse import collapse


def gossip_round(states, partner):
    """One matched gossip round over the dense peer-state matrix.

    Args:
      states: f32[P, C] — C = bucket window + 2 (N~ and q~ columns).
      partner: i32[P] involution (partner[l] == l -> idle row).

    Returns:
      f32[P, C] averaged states.
    """
    return avg_pairs(states, partner)


def ingest(xs, params, *, width):
    """Bucketize a batch of values into a dense counter window."""
    return bucketize(xs, params, width=width)


def collapse_step(hist, phase):
    """Collapse a dense window one level (gamma -> gamma^2)."""
    return collapse(hist, phase)


def lower_gossip_round(p, cols):
    """Lower :func:`gossip_round` for static shape [p, cols]."""
    states = jax.ShapeDtypeStruct((p, cols), jax.numpy.float32)
    partner = jax.ShapeDtypeStruct((p,), jax.numpy.int32)
    return jax.jit(lambda s, q: (gossip_round(s, q),)).lower(states, partner)


def lower_ingest(batch, width):
    """Lower :func:`ingest` for static batch/window sizes."""
    xs = jax.ShapeDtypeStruct((batch,), jax.numpy.float32)
    params = jax.ShapeDtypeStruct((2,), jax.numpy.float32)
    return jax.jit(
        lambda x, p: (ingest(x, p, width=width),)
    ).lower(xs, params)


def lower_collapse(width):
    """Lower :func:`collapse_step` for a static window size."""
    hist = jax.ShapeDtypeStruct((width,), jax.numpy.float32)
    phase = jax.ShapeDtypeStruct((1,), jax.numpy.float32)
    return jax.jit(lambda h, p: (collapse_step(h, p),)).lower(hist, phase)
