"""AOT compilation: lower the Layer-2 entry points to HLO text artifacts.

HLO *text* (not a serialized ``HloModuleProto``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
`xla` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Usage::

    python -m compile.aot --out-dir ../artifacts

Shapes are static in XLA, so a small ladder of network sizes is emitted;
the Rust `PjrtExecutor` picks the smallest artifact fitting the configured
network and pads idle rows.
"""

import argparse
import pathlib

from jax._src.lib import xla_client as xc

from compile import model

# (peers, bucket-window) ladder for the averaging round.
AVG_PAIRS_SHAPES = [(64, 128), (256, 512), (1024, 1024)]
# (batch, window) for bulk ingestion.
BUCKETIZE_SHAPES = [(4096, 512)]
# window widths for the collapse step.
COLLAPSE_WIDTHS = [512]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: pathlib.Path) -> list:
    """Lower and write every artifact; returns the written paths."""
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []

    def write(name: str, lowered):
        path = out_dir / f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        path.write_text(text)
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")

    for p, w in AVG_PAIRS_SHAPES:
        write(f"avg_pairs_p{p}_w{w}", model.lower_gossip_round(p, w + 2))
    for b, w in BUCKETIZE_SHAPES:
        write(f"bucketize_p{b}_w{w}", model.lower_ingest(b, w))
    for w in COLLAPSE_WIDTHS:
        write(f"collapse_p1_w{w}", model.lower_collapse(w))
    return written


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir",
        default="../artifacts",
        help="artifact directory (default: ../artifacts)",
    )
    args = parser.parse_args()
    emit(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
