"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

Every kernel in this package has an exact jnp twin here; pytest (with
hypothesis sweeps over shapes/values) asserts allclose between the two, and
the AOT artifacts are lowered from the Pallas versions only after this
signal is green.
"""

import jax
import jax.numpy as jnp


def ref_avg_pairs(states, partner):
    """Averaging round oracle.

    Args:
      states: f32[P, C] peer-state matrix (bucket window + N~ + q~ columns).
      partner: i32[P] exchange partner per row; ``partner[l] == l`` means
        idle. Must be an involution (``partner[partner[l]] == l``).

    Returns:
      f32[P, C]: rows of paired peers replaced by the pair average, idle
      rows untouched.
    """
    p = states.shape[0]
    gathered = jnp.take(states, partner, axis=0)
    active = (partner != jnp.arange(p, dtype=partner.dtype))[:, None]
    return jnp.where(active, 0.5 * (states + gathered), states)


def ref_bucketize(xs, params, width):
    """Bulk-ingest oracle: histogram of logarithmic bucket indices.

    Args:
      xs: f32[B] strictly positive values.
      params: f32[2] = (inv_ln_gamma, offset): the UDDSketch mapping
        ``i = ceil(ln x * inv_ln_gamma)`` shifted by the window offset.
      width: static window width W.

    Returns:
      f32[W]: counts per window slot; indices falling outside the window
      are clamped to the edge slots (the Rust caller sizes the window to
      cover the data, so clamping is a belt-and-braces guard).
    """
    inv_ln_gamma = params[0]
    offset = params[1]
    idx = jnp.ceil(jnp.log(xs) * inv_ln_gamma) - offset
    idx = jnp.clip(idx, 0, width - 1).astype(jnp.int32)
    return jnp.zeros(width, dtype=jnp.float32).at[idx].add(1.0)


def ref_collapse(hist, phase):
    """Uniform-collapse oracle (Algorithm 2) on a dense window.

    Window slot k holds the counter of logarithmic index ``o + k`` where
    ``o`` is the window offset. The collapse fuses indices ``(2j-1, 2j)``
    into ``j``; whether slot 0 starts a pair depends on the parity of
    ``o``.

    Args:
      hist: f32[W] dense counters (W even).
      phase: f32[1] — 1.0 when ``o`` is even (slot 0 pairs with the
        out-of-window index ``o-1``, so a zero pad is prepended), 0.0 when
        ``o`` is odd (slot 0 starts a pair).

    Returns:
      f32[W//2 + 1]: collapsed counters; entry j holds the counter of
      collapsed index ``ceil(o/2) + j``.
    """
    w = hist.shape[0]
    assert w % 2 == 0, "collapse window must be even"
    padded = jnp.concatenate(
        [jnp.zeros(1, hist.dtype), hist, jnp.zeros(1, hist.dtype)]
    )
    start = jnp.where(phase[0] > 0.5, 0, 1)
    window = jax.lax.dynamic_slice(padded, (start,), (w + 1,))
    pairs = window[:w].reshape(-1, 2).sum(axis=1)
    return jnp.concatenate([pairs, window[w:]])
