"""Pallas kernel: uniform collapse (Algorithm 2) on a dense window.

Pure data movement: pairs ``(2j-1, 2j)`` of logarithmic indices fuse into
``j``. A single resident block (the window is at most a few thousand f32)
with a dynamic one-slot shift selected by the window-offset parity.
``interpret=True`` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _collapse_kernel(hist_ref, phase_ref, out_ref):
    hist = hist_ref[...]
    w = hist.shape[0]
    padded = jnp.concatenate(
        [jnp.zeros(1, hist.dtype), hist, jnp.zeros(1, hist.dtype)]
    )
    start = jnp.where(phase_ref[0] > 0.5, 0, 1)
    window = jax.lax.dynamic_slice(padded, (start,), (w + 1,))
    pairs = window[:w].reshape(-1, 2).sum(axis=1)
    out_ref[...] = jnp.concatenate([pairs, window[w:]])


@functools.partial(jax.jit, static_argnames=())
def collapse(hist, phase):
    """Collapse a dense counter window one level (gamma -> gamma^2).

    Args:
      hist: f32[W] with W even; slot k holds the counter of index o + k.
      phase: f32[1] — 1.0 if the window offset o is even, else 0.0.

    Returns:
      f32[W//2 + 1]; slot j holds the counter of index ceil(o/2) + j.
    """
    w = hist.shape[0]
    assert w % 2 == 0, "collapse window must be even"
    return pl.pallas_call(
        _collapse_kernel,
        out_shape=jax.ShapeDtypeStruct((w // 2 + 1,), hist.dtype),
        interpret=True,
    )(hist, phase)
