"""Pallas kernel: bulk stream ingestion (bucketize + histogram).

The UDDSketch insert hot-spot is ``i = ceil(log_gamma x)`` followed by a
counter increment. For TPU the scalar scatter-add becomes a one-hot
reduction per value block (``onehot(idx)`` summed over the block maps onto
the MXU/VPU rather than serial scatter) with the grid streaming value
blocks through VMEM while the W-slot histogram stays resident as the
accumulator — the BlockSpec below expresses exactly that HBM<->VMEM
schedule. ``interpret=True`` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Values per grid step: one VMEM-friendly streaming block.
BLOCK = 1024


def _bucketize_kernel(xs_ref, params_ref, out_ref, *, width):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xs = xs_ref[...]
    inv_ln_gamma = params_ref[0]
    offset = params_ref[1]
    idx = jnp.ceil(jnp.log(xs) * inv_ln_gamma) - offset
    idx = jnp.clip(idx, 0, width - 1).astype(jnp.int32)
    onehot = (idx[:, None] == jnp.arange(width, dtype=jnp.int32)[None, :])
    out_ref[...] += onehot.astype(jnp.float32).sum(axis=0)


@functools.partial(jax.jit, static_argnames=("width",))
def bucketize(xs, params, *, width):
    """Histogram of logarithmic bucket indices over a dense window.

    Args:
      xs: f32[B] strictly positive values; B must be a multiple of
        :data:`BLOCK`.
      params: f32[2] = (inv_ln_gamma, offset).
      width: static window width W.

    Returns:
      f32[W] counts (out-of-window indices clamp to the edges).
    """
    b = xs.shape[0]
    assert b % BLOCK == 0, f"batch {b} must be a multiple of {BLOCK}"
    grid = b // BLOCK
    return pl.pallas_call(
        functools.partial(_bucketize_kernel, width=width),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),  # stream value blocks
            pl.BlockSpec((2,), lambda i: (0,)),      # params resident
        ],
        out_specs=pl.BlockSpec((width,), lambda i: (0,)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((width,), jnp.float32),
        interpret=True,
    )(xs, params)
