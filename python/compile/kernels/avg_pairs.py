"""Pallas kernel: one distributed-averaging gossip round.

The whole-network round is a gather + elementwise average over the dense
peer-state matrix ``[P, C]`` (C = bucket window + 2 scalar columns). On a
real TPU the matrix tiles into VMEM row-blocks (a 256x1026 f32 block is
~1 MB, comfortably under VMEM) and each block streams HBM->VMEM once per
round — see DESIGN.md §Hardware-Adaptation. The partner gather crosses row
blocks, so the kernel keeps the full state resident (grid=1) and relies on
BlockSpec only for the documented tiling estimate; ``interpret=True`` is
mandatory on CPU (real TPU lowering emits a Mosaic custom-call the CPU
PJRT client cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _avg_pairs_kernel(states_ref, partner_ref, out_ref):
    states = states_ref[...]
    partner = partner_ref[...]
    p = states.shape[0]
    gathered = jnp.take(states, partner, axis=0)
    active = (partner != jnp.arange(p, dtype=partner.dtype))[:, None]
    out_ref[...] = jnp.where(active, 0.5 * (states + gathered), states)


@functools.partial(jax.jit, static_argnames=())
def avg_pairs(states, partner):
    """Average paired rows of the peer-state matrix.

    Args:
      states: f32[P, C].
      partner: i32[P] involution; ``partner[l] == l`` marks idle rows.

    Returns:
      f32[P, C] averaged states.
    """
    return pl.pallas_call(
        _avg_pairs_kernel,
        out_shape=jax.ShapeDtypeStruct(states.shape, states.dtype),
        interpret=True,
    )(states, partner)
