//! Token-level Rust scanner.
//!
//! Just enough lexing to make the rules in this crate robust against
//! comments, string literals, raw strings, and `'a` vs `'x'` ambiguity —
//! no syntax tree, no rustc. Every rule works on the flat token stream
//! plus brace-depth bookkeeping.

/// Token class. `Str` keeps the literal's unquoted text (the spec
/// checker reads match-arm key literals); the other literal kinds drop
/// their payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Punct,
    Str,
    Lifetime,
    Char,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Token {
    fn new(kind: Kind, text: impl Into<String>, line: u32) -> Self {
        Token {
            kind,
            text: text.into(),
            line,
        }
    }

    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == Kind::Ident && self.text == text
    }
}

/// Lex `src` into a token stream. Comments (line, nested block) and
/// whitespace vanish; literals collapse to a single token each.
pub fn tokenize(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment (incl. doc comments)
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        // block comment, nested
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw strings: r"..."  r#"..."#  br"..."  br#"..."#
        if c == b'r' || c == b'b' {
            let mut k = i;
            if b[k] == b'b' && k + 1 < n && b[k + 1] == b'r' {
                k += 1;
            }
            if b[k] == b'r' {
                let mut hashes = 0usize;
                let mut j = k + 1;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    let start = j + 1;
                    let mut close = String::from("\"");
                    close.push_str(&"#".repeat(hashes));
                    let rest = &src[start..];
                    let end = match rest.find(&close) {
                        Some(p) => start + p,
                        None => n,
                    };
                    line += src[i..end].matches('\n').count() as u32;
                    toks.push(Token::new(Kind::Str, &src[start..end], line));
                    i = (end + close.len()).min(n);
                    continue;
                }
            }
        }
        // plain / byte strings
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            let start = if c == b'b' { i + 2 } else { i + 1 };
            let mut j = start;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    break;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                j += 1;
            }
            let end = j.min(n);
            toks.push(Token::new(Kind::Str, &src[start..end], line));
            i = end + 1;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            let j = i + 1;
            if j < n && (b[j].is_ascii_alphabetic() || b[j] == b'_') {
                let mut k = j;
                while k < n && (b[k].is_ascii_alphanumeric() || b[k] == b'_') {
                    k += 1;
                }
                if k < n && b[k] == b'\'' {
                    // 'x' (or 'ab' which is invalid Rust anyway)
                    toks.push(Token::new(Kind::Char, "", line));
                    i = k + 1;
                    continue;
                }
                toks.push(Token::new(Kind::Lifetime, &src[j..k], line));
                i = k;
                continue;
            }
            // '\n', '\'', '(' …
            if j < n && b[j] == b'\\' {
                let mut k = j + 2;
                while k < n && b[k] != b'\'' {
                    k += 1;
                }
                i = k + 1;
            } else if j + 1 < n && b[j + 1] == b'\'' {
                i = j + 2;
            } else {
                i = j + 1;
            }
            toks.push(Token::new(Kind::Char, "", line));
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            toks.push(Token::new(Kind::Ident, &src[i..j], line));
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let d = b[j];
                if d.is_ascii_alphanumeric() || d == b'_' {
                    j += 1;
                    continue;
                }
                // `1.5` continues the number; `1..n` and `1.method()` don't
                if d == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                    continue;
                }
                break;
            }
            toks.push(Token::new(Kind::Num, &src[i..j], line));
            i = j;
            continue;
        }
        toks.push(Token::new(Kind::Punct, &src[i..i + 1], line));
        i += 1;
    }
    toks
}

/// Index of the `)`/`}`/`]` matching the opener at `open_idx`.
pub fn matching(toks: &[Token], open_idx: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    let mut i = open_idx;
    while i < toks.len() {
        if toks[i].is(open) {
            depth += 1;
        } else if toks[i].is(close) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Token-index spans (inclusive) of `#[cfg(test)] mod …` bodies.
fn test_mod_spans(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let is_cfg_test = toks[i].is("#")
            && toks[i + 1].is("[")
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is("(")
            && toks[i + 4].is_ident("test")
            && toks[i + 5].is(")")
            && toks[i + 6].is("]");
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // skip any further attributes between the cfg and the item
        let mut j = i + 7;
        while j < toks.len() && toks[j].is("#") {
            let open = j + 1;
            if open < toks.len() && toks[open].is("[") {
                j = matching(toks, open, "[", "]") + 1;
            } else {
                break;
            }
        }
        if j < toks.len() && toks[j].is_ident("mod") {
            let mut k = j;
            while k < toks.len() && !toks[k].is("{") {
                k += 1;
            }
            if k < toks.len() {
                let m = matching(toks, k, "{", "}");
                spans.push((i, m));
                i = m + 1;
                continue;
            }
        }
        i += 1;
    }
    spans
}

/// The token stream with every `#[cfg(test)] mod` body removed — rules
/// check production code only.
pub fn strip_tests(toks: Vec<Token>) -> Vec<Token> {
    let spans = test_mod_spans(&toks);
    if spans.is_empty() {
        return toks;
    }
    toks.into_iter()
        .enumerate()
        .filter(|(idx, _)| !spans.iter().any(|&(a, b)| a <= *idx && *idx <= b))
        .map(|(_, t)| t)
        .collect()
}

/// A function item's name and body span (`{` … `}` token indices).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub body_start: usize,
    pub body_end: usize,
}

/// Every `fn` item (including nested ones) with a body. Trait method
/// declarations without bodies are skipped.
pub fn functions(toks: &[Token]) -> Vec<FnSpan> {
    let mut res = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if toks[i].is_ident("fn") && toks[i + 1].kind == Kind::Ident {
            let name = toks[i + 1].text.clone();
            // the argument list's matching `)` …
            let mut j = i + 2;
            while j < toks.len() && !toks[j].is("(") {
                j += 1;
            }
            if j >= toks.len() {
                break;
            }
            let args_end = matching(toks, j, "(", ")");
            // … then the first `{` (or `;` for a bodyless declaration)
            let mut k = args_end;
            while k < toks.len() && !toks[k].is("{") && !toks[k].is(";") {
                k += 1;
            }
            if k >= toks.len() || toks[k].is(";") {
                i += 2;
                continue;
            }
            let body_end = matching(toks, k, "{", "}");
            res.push(FnSpan {
                name,
                body_start: k,
                body_end,
            });
            i += 2;
            continue;
        }
        i += 1;
    }
    res
}

/// Name of the function span enclosing token `idx`, if any (innermost).
pub fn enclosing_fn(fns: &[FnSpan], idx: usize) -> Option<&str> {
    fns.iter()
        .filter(|f| f.body_start <= idx && idx <= f.body_end)
        .min_by_key(|f| f.body_end - f.body_start)
        .map(|f| f.name.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_vanish() {
        let toks = tokenize("let a = \"// not a comment\"; // real\n/* b */ b");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["let", "a", "b"]);
        assert_eq!(
            toks.iter().filter(|t| t.kind == Kind::Str).count(),
            1,
            "one string literal"
        );
    }

    #[test]
    fn string_text_is_kept() {
        let toks = tokenize("match key { \"alpha\" | \"a\" => 1 }");
        let strs: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, ["alpha", "a"]);
    }

    #[test]
    fn lifetime_vs_char() {
        let toks = tokenize("fn f<'a>(x: &'a str) { let c = 'x'; }");
        assert!(toks.iter().any(|t| t.kind == Kind::Lifetime && t.text == "a"));
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Char).count(), 1);
    }

    #[test]
    fn nested_block_comments() {
        let toks = tokenize("/* a /* b */ c */ x");
        assert_eq!(toks.len(), 1);
        assert!(toks[0].is_ident("x"));
    }

    #[test]
    fn raw_strings() {
        let toks = tokenize("let s = r#\"has \"quotes\" inside\"#; y");
        assert!(toks.iter().any(|t| t.kind == Kind::Str));
        assert!(toks.last().unwrap().is_ident("y"));
    }

    #[test]
    fn test_mods_are_stripped() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests { fn t() { bad(); } }";
        let toks = strip_tests(tokenize(src));
        assert!(!toks.iter().any(|t| t.is_ident("bad")));
        assert!(toks.iter().any(|t| t.is_ident("prod")));
    }

    #[test]
    fn function_spans() {
        let src = "impl A { fn one(&self) -> usize { 1 } fn two() {} }";
        let toks = tokenize(src);
        let fns = functions(&toks);
        assert_eq!(fns.len(), 2);
        assert_eq!(fns[0].name, "one");
        assert_eq!(fns[1].name, "two");
    }

    #[test]
    fn line_numbers_track() {
        let toks = tokenize("a\nb\n\nc");
        let lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, [1, 2, 4]);
    }
}
