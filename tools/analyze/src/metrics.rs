//! Metric-catalogue cross-checker (`metrics-sync`).
//!
//! The observability plane registers every `dudd_*` family in
//! `rust/src/obs/` (the `NodeMetrics` constructor plus the labelled
//! `RejectCounters`/`RestartCounters` bundles), and
//! `docs/OBSERVABILITY.md` carries the operator-facing catalogue as
//! `| Metric | Kind | Meaning |` tables. Both sides drift silently: a
//! family added without a catalogue row is invisible to operators, and
//! a row whose family was renamed documents a ghost. This rule parses
//! both sides and diffs them bidirectionally, exactly as `spec-sync`
//! does for the wire tables:
//!
//! * **code side** — every string literal naming a `dudd_*` family in
//!   production (non-test) code under `rust/src/obs/`. That covers the
//!   registry registration calls, the labelled counter bundles, and the
//!   `dudd-observe` scraper's reads — a retired family the observatory
//!   still looks for is drift too. Label selectors are stripped
//!   (`dudd_rejects_total{reason="busy"}` → `dudd_rejects_total`) and
//!   strings that are not well-formed family names (help text, `expect`
//!   messages) are ignored.
//! * **doc side** — backticked names from the first cell of every
//!   markdown table whose first header cell is `Metric`.

use crate::lexer::{strip_tests, tokenize, Kind};
use crate::report::Finding;
use std::collections::BTreeMap;

/// True for a well-formed Prometheus family name in this crate's
/// convention: `dudd_` plus at least one `[a-z0-9_]` character.
fn is_family_name(name: &str) -> bool {
    name.len() > "dudd_".len()
        && name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// `dudd_*` family names referenced by production code in `text`,
/// mapped to the first line each appears on.
pub fn code_metrics(text: &str) -> BTreeMap<String, u32> {
    let mut out = BTreeMap::new();
    for tok in strip_tests(tokenize(text)) {
        if tok.kind != Kind::Str || !tok.text.starts_with("dudd_") {
            continue;
        }
        let name = tok.text.split('{').next().unwrap_or("");
        if is_family_name(name) {
            out.entry(name.to_string()).or_insert(tok.line);
        }
    }
    out
}

/// Catalogue rows of `md`: backticked first-cell names of every table
/// headed `Metric | …`, label selectors stripped, mapped to their
/// 1-based line.
pub fn doc_metrics(md: &str) -> BTreeMap<String, u32> {
    let mut out = BTreeMap::new();
    let mut grab = false;
    for (idx, raw) in md.lines().enumerate() {
        let line = raw.trim();
        if !line.starts_with('|') {
            grab = false;
            continue;
        }
        let cells: Vec<&str> = line.trim_matches('|').split('|').map(str::trim).collect();
        let first = cells.first().copied().unwrap_or("");
        if first.eq_ignore_ascii_case("metric") {
            grab = true;
            continue;
        }
        if !grab || first.chars().all(|c| "-: ".contains(c)) {
            continue;
        }
        let Some(ticked) = backticked(first) else {
            continue;
        };
        let name = ticked.split('{').next().unwrap_or("").to_string();
        if name.starts_with("dudd_") {
            out.entry(name).or_insert(idx as u32 + 1);
        }
    }
    out
}

fn backticked(cell: &str) -> Option<String> {
    let start = cell.find('`')? + 1;
    let end = start + cell[start..].find('`')?;
    Some(cell[start..end].to_string())
}

/// Diff both directions. `sources` are the `rust/src/obs/` files as
/// (repo-relative path, text); `md` is `docs/OBSERVABILITY.md`.
pub fn check(sources: &[(String, String)], md: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut code: BTreeMap<String, (&str, u32)> = BTreeMap::new();
    for (rel, text) in sources {
        for (name, line) in code_metrics(text) {
            code.entry(name).or_insert((rel.as_str(), line));
        }
    }
    let doc = doc_metrics(md);
    // An empty side means the extraction idiom broke, not that the
    // catalogue emptied — fail loudly instead of passing vacuously.
    if code.is_empty() {
        findings.push(Finding::new(
            "metrics-sync",
            "rust/src/obs/mod.rs",
            0,
            "could not extract any `dudd_*` family references from rust/src/obs/",
        ));
    }
    if doc.is_empty() {
        findings.push(Finding::new(
            "metrics-sync",
            "docs/OBSERVABILITY.md",
            0,
            "could not find any `Metric | …` catalogue table rows",
        ));
    }
    if !findings.is_empty() {
        return findings;
    }
    for (name, (rel, line)) in &code {
        if !doc.contains_key(name) {
            findings.push(Finding::new(
                "metrics-sync",
                rel,
                *line,
                format!(
                    "metric family `{name}` is referenced in code but missing \
                     from the docs/OBSERVABILITY.md catalogue"
                ),
            ));
        }
    }
    for (name, line) in &doc {
        if !code.contains_key(name) {
            findings.push(Finding::new(
                "metrics-sync",
                "docs/OBSERVABILITY.md",
                *line,
                format!(
                    "metric family `{name}` is in the catalogue but never \
                     referenced in rust/src/obs/"
                ),
            ));
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    const OBS_SRC: &str = r#"
pub fn register(r: &Registry) -> Result<()> {
    r.counter("dudd_rounds_total", "Gossip rounds executed.")?;
    r.gauge("dudd_drift", "Largest relative probe drift.")?;
    r.histogram_with(
        "dudd_round_phase_seconds",
        "Wall clock per phase.",
        &[("phase", "exchange")],
    )?;
    let c = |cause: &str| r.counter_with("dudd_restarts_total", "Restarts.", &[("cause", cause)]);
    c("view_change").expect("dudd_* families are statically valid");
    Ok(())
}

pub fn read(m: &Map) -> f64 {
    m.get("dudd_exchange_rtt_seconds{quantile=\"0.99\"}")
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _ = "dudd_test_only_total";
    }
}
"#;

    const CATALOG_MD: &str = r#"
## Catalogue

| Metric | Kind | Meaning |
|---|---|---|
| `dudd_rounds_total` | counter | Rounds executed |
| `dudd_drift` | gauge | Probe drift |
| `dudd_round_phase_seconds{phase=…}` | summary | Per-phase wall clock |

### More

| Metric | Kind | Meaning |
|---|---|---|
| `dudd_restarts_total{cause=…}` | counter | Restarts by cause |
| `dudd_exchange_rtt_seconds` | summary | Exchange RTT |
"#;

    fn sources() -> Vec<(String, String)> {
        vec![("rust/src/obs/fixture.rs".to_string(), OBS_SRC.to_string())]
    }

    #[test]
    fn code_extraction_strips_labels_and_skips_tests_and_prose() {
        let code = code_metrics(OBS_SRC);
        let names: Vec<&str> = code.keys().map(String::as_str).collect();
        assert_eq!(
            names,
            [
                "dudd_drift",
                "dudd_exchange_rtt_seconds",
                "dudd_restarts_total",
                "dudd_round_phase_seconds",
                "dudd_rounds_total",
            ]
        );
    }

    #[test]
    fn doc_extraction_reads_every_metric_table() {
        let doc = doc_metrics(CATALOG_MD);
        assert_eq!(doc.len(), 5);
        assert!(doc.contains_key("dudd_restarts_total"));
        assert!(doc.contains_key("dudd_round_phase_seconds"));
    }

    #[test]
    fn in_sync_catalogue_passes() {
        let f = check(&sources(), CATALOG_MD);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn both_drift_directions_flagged() {
        let md = CATALOG_MD
            .replace("| `dudd_drift` | gauge | Probe drift |\n", "")
            .replace(
                "| `dudd_exchange_rtt_seconds` | summary | Exchange RTT |",
                "| `dudd_exchange_rtt_seconds` | summary | Exchange RTT |\n\
                 | `dudd_ghost_total` | counter | Removed long ago |",
            );
        let f = check(&sources(), &md);
        assert!(
            f.iter()
                .any(|x| x.message.contains("`dudd_drift` is referenced in code")),
            "{f:?}"
        );
        assert!(
            f.iter()
                .any(|x| x.message.contains("`dudd_ghost_total` is in the catalogue")),
            "{f:?}"
        );
    }

    #[test]
    fn empty_sides_fail_loudly() {
        let f = check(&[], CATALOG_MD);
        assert!(
            f.iter().any(|x| x.message.contains("could not extract")),
            "{f:?}"
        );
        let f = check(&sources(), "no tables here");
        assert!(
            f.iter().any(|x| x.message.contains("could not find")),
            "{f:?}"
        );
    }
}
