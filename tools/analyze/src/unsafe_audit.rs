//! Unsafe/panic audit.
//!
//! * `unsafe` — the only module allowed to contain `unsafe` code is
//!   `service/swap.rs` (the [`ArcSwapCell`] reclamation scheme, which
//!   the loom model and the Miri lane cover dynamically). Everything
//!   else must carry `#![forbid(unsafe_code)]` at its module root so
//!   the compiler enforces the same pin.
//! * `lock-unwrap` — `.lock().expect(…)` / `.unwrap()` outside a named
//!   `lock_*` helper. Poisoning policy lives in exactly one place per
//!   mutex; ad-hoc unwraps drift and hide the policy from review.
//!
//! [`ArcSwapCell`]: ../../rust/src/service/swap.rs

use crate::lexer::{enclosing_fn, functions, strip_tests, tokenize, Kind};
use crate::report::Finding;

/// The single file allowed to contain `unsafe`.
const UNSAFE_ALLOWED: &str = "rust/src/service/swap.rs";

/// Module roots that must carry `#![forbid(unsafe_code)]`. `lib.rs` and
/// `service/mod.rs` cannot: a crate- or service-level forbid would
/// cascade into `swap.rs`.
pub fn requires_forbid(path: &str) -> bool {
    let Some(rel) = path.strip_prefix("rust/src/") else {
        return false;
    };
    match rel {
        "lib.rs" => false,
        "cli.rs" | "config.rs" | "main.rs" => true,
        _ => {
            if let Some(service_file) = rel.strip_prefix("service/") {
                !service_file.contains('/')
                    && service_file != "mod.rs"
                    && service_file != "swap.rs"
            } else {
                // other subtrees: the mod.rs root covers the subtree
                rel.ends_with("/mod.rs")
            }
        }
    }
}

fn has_forbid(src: &str) -> bool {
    let toks = tokenize(src);
    toks.windows(6).any(|w| {
        w[0].is("#")
            && w[1].is("!")
            && w[2].is("[")
            && w[3].is_ident("forbid")
            && w[4].is("(")
            && w[5].is_ident("unsafe_code")
    })
}

pub fn check_file(path: &str, src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let toks = strip_tests(tokenize(src));
    if path != UNSAFE_ALLOWED {
        for t in &toks {
            if t.is_ident("unsafe") {
                findings.push(Finding::new(
                    "unsafe",
                    path,
                    t.line,
                    "unsafe outside service/swap.rs — the audit pins all \
                     unsafe code to the ArcSwapCell reclamation scheme",
                ));
            }
        }
    }
    if requires_forbid(path) && !has_forbid(src) {
        findings.push(Finding::new(
            "unsafe",
            path,
            0,
            "missing #![forbid(unsafe_code)] at this module root",
        ));
    }
    // lock-unwrap: `.lock().expect(…)` outside a named lock_* helper
    let fns = functions(&toks);
    for (i, t) in toks.iter().enumerate() {
        let is_lock_call = t.kind == Kind::Ident
            && (t.text == "lock" || t.text == "try_lock")
            && i > 0
            && toks[i - 1].is(".")
            && i + 4 < toks.len()
            && toks[i + 1].is("(")
            && toks[i + 2].is(")")
            && toks[i + 3].is(".")
            && (toks[i + 4].is_ident("unwrap") || toks[i + 4].is_ident("expect"));
        if !is_lock_call {
            continue;
        }
        let fn_name = enclosing_fn(&fns, i).unwrap_or("?");
        if fn_name != "lock" && !fn_name.starts_with("lock_") {
            findings.push(Finding::new(
                "lock-unwrap",
                path,
                t.line,
                format!(
                    "{} on a {}() result in fn {fn_name} — route through a \
                     named lock_* helper so the poisoning policy has one home",
                    toks[i + 4].text, t.text
                ),
            ));
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsafe_outside_swap_flagged() {
        let f = check_file(
            "rust/src/sketch/codec.rs",
            "#![forbid(unsafe_code)]\nfn f() { unsafe { std::hint::unreachable_unchecked() } }",
        );
        // (contradictory file, but the scanner sees the token)
        assert!(f.iter().any(|x| x.rule == "unsafe"), "{f:?}");
    }

    #[test]
    fn unsafe_in_swap_allowed() {
        let f = check_file(
            "rust/src/service/swap.rs",
            "fn f() { unsafe { core::ptr::null::<u8>(); } }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn missing_forbid_flagged() {
        let f = check_file("rust/src/sketch/mod.rs", "pub mod codec;");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("forbid"));
    }

    #[test]
    fn forbid_requirement_scope() {
        assert!(requires_forbid("rust/src/sketch/mod.rs"));
        assert!(requires_forbid("rust/src/service/transport.rs"));
        assert!(requires_forbid("rust/src/config.rs"));
        assert!(!requires_forbid("rust/src/lib.rs"));
        assert!(!requires_forbid("rust/src/service/mod.rs"));
        assert!(!requires_forbid("rust/src/service/swap.rs"));
        assert!(!requires_forbid("rust/src/sketch/codec.rs"));
    }

    #[test]
    fn lock_expect_outside_helper_flagged() {
        let src = "#![forbid(unsafe_code)]\nimpl A { fn work(&self) { let g = self.state.lock().expect(\"poisoned\"); } }";
        let f = check_file("rust/src/obs/registry.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "lock-unwrap");
    }

    #[test]
    fn lock_expect_inside_helper_allowed() {
        let src = "#![forbid(unsafe_code)]\nimpl A { fn lock_state(&self) -> G { self.state.lock().expect(\"poisoned\") } }";
        let f = check_file("rust/src/obs/registry.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }
}
