//! CLI for the in-tree static analysis suite.
//!
//! ```text
//! dudd-analyze [--root DIR] [--json] [RULE ...]
//! ```
//!
//! With no rule arguments (or `all`) every rule runs. Exit status: 0
//! when clean, 1 when any finding is reported, 2 on usage or I/O
//! errors — so CI can distinguish "violations" from "broken run".

use dudd_analyze::{report, run_rules, RULES};
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> String {
    format!(
        "usage: dudd-analyze [--root DIR] [--json] [RULE ...]\n\
         rules: all (default), {}",
        RULES.join(", ")
    )
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json = false;
    let mut rules: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("--root requires a directory\n{}", usage());
                    return ExitCode::from(2);
                }
            },
            "--json" => json = true,
            "--help" | "-h" => {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            "all" => rules.extend(RULES.iter().map(|r| r.to_string())),
            r if RULES.contains(&r) => rules.push(r.to_string()),
            other => {
                eprintln!("unknown argument '{other}'\n{}", usage());
                return ExitCode::from(2);
            }
        }
    }
    if rules.is_empty() {
        rules.extend(RULES.iter().map(|r| r.to_string()));
    }
    rules.dedup();

    let rule_refs: Vec<&str> = rules.iter().map(String::as_str).collect();
    let findings = match run_rules(&rule_refs, &root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("dudd-analyze: {e}");
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", report::to_json(&findings));
    } else {
        for f in &findings {
            println!("{f}");
        }
        if findings.is_empty() {
            eprintln!("dudd-analyze: {} rule(s) clean", rule_refs.len());
        } else {
            eprintln!("dudd-analyze: {} finding(s)", findings.len());
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
