//! `dudd-analyze` — the repo's in-tree static analysis suite.
//!
//! A zero-dependency, token-level scanner over `rust/src/` that turns
//! the invariants written in module docs and `docs/PROTOCOL.md` into
//! hard CI failures. Rules (see `docs/ANALYSIS.md` for the catalogue):
//!
//! * `lock-order` — lock graph acyclicity, slot-pair ordering, no
//!   socket I/O under control-plane locks ([`locks`]);
//! * `determinism` — no ambient time outside the `Clock` abstraction,
//!   no hash-ordered collections in wire/trace paths ([`determinism`]);
//! * `spec-sync` — codec enums, protocol version, restart-cause codes,
//!   and config keys vs the PROTOCOL.md tables, both directions
//!   ([`spec`]);
//! * `metrics-sync` — `dudd_*` metric families referenced in
//!   `rust/src/obs/` vs the OBSERVABILITY.md catalogue tables, both
//!   directions ([`metrics`]);
//! * `unsafe-audit` — `unsafe` pinned to `service/swap.rs`,
//!   `#![forbid(unsafe_code)]` elsewhere, lock poisoning policy routed
//!   through `lock_*` helpers ([`unsafe_audit`]);
//! * `counter-audit` — no unchecked subtraction between monotonic
//!   counter reads ([`counters`]).
//!
//! The scanner is deliberately not a compiler: it lexes real Rust
//! tokens (strings, raw strings, nested comments, lifetimes) but
//! resolves nothing. Every rule is written against the idioms this
//! codebase actually uses, and escape hatches go through
//! `tools/analyze/allowlist.txt` with a reason, never through silence.

pub mod allow;
pub mod counters;
pub mod determinism;
pub mod lexer;
pub mod locks;
pub mod metrics;
pub mod report;
pub mod spec;
pub mod unsafe_audit;

use crate::allow::Allowlist;
use crate::report::Finding;
use std::fs;
use std::io;
use std::path::Path;

/// The rule names accepted on the command line, in run order.
pub const RULES: &[&str] = &[
    "lock-order",
    "determinism",
    "spec-sync",
    "metrics-sync",
    "unsafe-audit",
    "counter-audit",
];

/// A source file addressed by its repo-relative, `/`-separated path.
pub struct SourceFile {
    pub rel: String,
    pub text: String,
}

/// All `.rs` files under `<root>/rust/src`, sorted by relative path so
/// reports and JSON output are stable across platforms.
pub fn collect_sources(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut out = Vec::new();
    walk(&root.join("rust").join("src"), "rust/src", &mut out)?;
    out.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(out)
}

fn walk(dir: &Path, rel: &str, out: &mut Vec<SourceFile>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let child_rel = format!("{rel}/{name}");
        let path = entry.path();
        if path.is_dir() {
            walk(&path, &child_rel, out)?;
        } else if name.ends_with(".rs") {
            out.push(SourceFile {
                rel: child_rel,
                text: fs::read_to_string(&path)?,
            });
        }
    }
    Ok(())
}

fn load_allowlist(root: &Path) -> Allowlist {
    match fs::read_to_string(root.join("tools").join("analyze").join("allowlist.txt")) {
        Ok(text) => Allowlist::parse(&text),
        Err(_) => Allowlist::default(),
    }
}

fn read_doc(root: &Path, rel: &str, rule: &str, findings: &mut Vec<Finding>) -> String {
    match fs::read_to_string(root.join(rel.replace('/', std::path::MAIN_SEPARATOR_STR))) {
        Ok(text) => text,
        Err(e) => {
            findings.push(Finding::new(rule, rel, 0, format!("cannot read: {e}")));
            String::new()
        }
    }
}

/// Run one rule against the repo at `root`.
pub fn run_rule(rule: &str, root: &Path, sources: &[SourceFile]) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    match rule {
        "lock-order" => {
            for f in sources {
                findings.extend(locks::check_file(&f.rel, &f.text));
            }
        }
        "determinism" => {
            let allow = load_allowlist(root);
            for f in sources {
                findings.extend(determinism::check_file(&f.rel, &f.text, &allow));
            }
        }
        "unsafe-audit" => {
            for f in sources {
                findings.extend(unsafe_audit::check_file(&f.rel, &f.text));
            }
        }
        "counter-audit" => {
            for f in sources {
                findings.extend(counters::check_file(&f.rel, &f.text));
            }
        }
        "spec-sync" => {
            let inputs = spec::SpecInputs {
                codec: read_doc(root, "rust/src/sketch/codec.rs", rule, &mut findings),
                membership: read_doc(root, "rust/src/service/membership.rs", rule, &mut findings),
                gossip_loop: read_doc(root, "rust/src/service/gossip_loop.rs", rule, &mut findings),
                config: read_doc(root, "rust/src/config.rs", rule, &mut findings),
                protocol_md: read_doc(root, "docs/PROTOCOL.md", rule, &mut findings),
                readme_md: read_doc(root, "README.md", rule, &mut findings),
            };
            if findings.is_empty() {
                findings.extend(spec::check(&inputs));
            }
        }
        "metrics-sync" => {
            let md = read_doc(root, "docs/OBSERVABILITY.md", rule, &mut findings);
            if findings.is_empty() {
                let obs: Vec<(String, String)> = sources
                    .iter()
                    .filter(|f| f.rel.starts_with("rust/src/obs/"))
                    .map(|f| (f.rel.clone(), f.text.clone()))
                    .collect();
                findings.extend(metrics::check(&obs, &md));
            }
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("unknown rule '{other}' (expected one of: {})", RULES.join(", ")),
            ));
        }
    }
    Ok(findings)
}

/// Run every rule; findings come back grouped in [`RULES`] order.
pub fn run_rules(rules: &[&str], root: &Path) -> io::Result<Vec<Finding>> {
    let sources = collect_sources(root)?;
    let mut findings = Vec::new();
    for rule in rules {
        findings.extend(run_rule(rule, root, &sources)?);
    }
    Ok(findings)
}
