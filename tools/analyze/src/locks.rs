//! Lock-order checker.
//!
//! Extracts every lock acquisition (`lock_ctl()`, `lock_slot(i)`,
//! `lock_*()` helpers, `.lock()` / `.try_lock()` on a field) from each
//! function, tracks guard liveness by brace depth, and enforces the
//! locking model written in `service/gossip_loop.rs`:
//!
//! * the per-file lock graph must be acyclic, and `ctl` must never be
//!   taken while already holding it is fine — but a slot acquired under
//!   `ctl` inverts the documented `slots → ctl` order and is rejected;
//! * a second slot may only be acquired with ascending-index evidence
//!   (both literals ordered, or the canonical `let lo = a.min(b)` /
//!   `let hi = a.max(b)` pair);
//! * no socket operation (connect/read/write/exchange helpers) may be
//!   reachable — directly or through an intra-file call chain — while
//!   holding any lock other than a slot or the round gate.
//!
//! Guard liveness is approximated the way the codebase actually writes
//! guards: `let g = self.lock_x();` lives to the end of its block,
//! `self.lock_x().field` is statement-transient, `drop(g)` ends a guard
//! early, and a `match x.try_lock()` head is conservatively held to the
//! end of the function (the serve path stashes such guards in a `Vec`).

use crate::lexer::{functions, matching, strip_tests, tokenize, Kind, Token};
use crate::report::Finding;
use std::collections::{BTreeMap, BTreeSet};

/// Method/function names treated as socket operations.
const SOCKET_METHODS: &[&str] = &[
    "connect",
    "read",
    "read_exact",
    "read_to_end",
    "write",
    "write_all",
    "flush",
    "peek",
    "accept",
    "shutdown",
    "set_read_timeout",
    "set_write_timeout",
    "set_nonblocking",
    "open_remote",
    "exchange_on",
    "exchange_membership",
    "join_remote",
    "deliver",
];

/// Lock classes allowed to span a socket operation: the initiator's
/// own slot (push–pull by design) and the outermost round gate.
const SOCKET_OK_HOLDERS: &[&str] = &["slot", "slot_all", "gate", "round_gate"];

fn socket_ok(class: &str) -> bool {
    SOCKET_OK_HOLDERS.contains(&class)
}

fn is_socket_method(name: &str) -> bool {
    SOCKET_METHODS.contains(&name)
}

struct Acq {
    class: String,
    blocking: bool,
    args: Vec<Token>,
    /// Index of the last token of the acquisition expression (closing
    /// paren, possibly of a chained `.expect(…)`).
    end: usize,
}

/// If `toks[i]` starts a lock acquisition, classify it.
fn acquisition_at(toks: &[Token], i: usize) -> Option<Acq> {
    if toks[i].kind != Kind::Ident {
        return None;
    }
    let name = toks[i].text.as_str();
    if i + 1 >= toks.len() || !toks[i + 1].is("(") {
        return None;
    }
    let mut end = matching(toks, i + 1, "(", ")");
    let args: Vec<Token> = toks[i + 2..end].to_vec();
    // a chained `.expect(…)` / `.unwrap()` is still the same guard
    while end + 2 < toks.len()
        && toks[end + 1].is(".")
        && (toks[end + 2].is_ident("expect") || toks[end + 2].is_ident("unwrap"))
    {
        end = matching(toks, end + 3, "(", ")");
    }
    let prev = if i > 0 { toks[i - 1].text.as_str() } else { "" };
    let acq = |class: &str, blocking: bool| {
        Some(Acq {
            class: class.to_string(),
            blocking,
            args: args.clone(),
            end,
        })
    };
    match name {
        "lock_slot" => acq("slot", true),
        "lock_local_slots" => acq("slot_all", true),
        "lock_ctl" => acq("ctl", true),
        "lock" if prev == "." => {
            let recv = receiver_name(toks, i - 1);
            acq(&recv, true)
        }
        "try_lock" if prev == "." => {
            let recv = receiver_name(toks, i - 1);
            let class = if recv == "slots" { "slot" } else { &recv };
            acq(class, false)
        }
        _ if name.starts_with("lock_") => acq(&name["lock_".len()..], true),
        _ => None,
    }
}

/// The field a `.lock()` receiver names: `self.inner.lock()` → `inner`,
/// `self.slots[i].lock()` → `slots`.
fn receiver_name(toks: &[Token], dot_idx: usize) -> String {
    let mut j = dot_idx as isize - 1;
    if j >= 0 && toks[j as usize].is("]") {
        let mut depth = 0isize;
        while j >= 0 {
            let t = &toks[j as usize];
            if t.is("]") {
                depth += 1;
            } else if t.is("[") {
                depth -= 1;
                if depth == 0 {
                    j -= 1;
                    break;
                }
            }
            j -= 1;
        }
    }
    if j >= 0 && toks[j as usize].kind == Kind::Ident {
        toks[j as usize].text.clone()
    } else {
        "?".to_string()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Until {
    /// Guard dies when its enclosing block closes (depth falls below).
    Depth(i32),
    /// Transient: dies at the next `;`.
    Stmt,
    /// Conservatively held to the end of the function.
    Fn,
}

struct Held {
    class: String,
    name: Option<String>,
    args: Vec<Token>,
    until: Until,
}

struct FnInfo {
    name: String,
    edges: Vec<(String, String, u32)>,
    /// (line, classes held) at each socket operation.
    sockets: Vec<(u32, Vec<String>)>,
    /// (callee, line, classes held) at each intra-file call site.
    calls: Vec<(String, u32, Vec<String>)>,
    /// Lines where a slot pair was acquired without ordering evidence.
    pair_violations: Vec<u32>,
    /// Blocking classes acquired anywhere in the body.
    acquired: BTreeSet<String>,
}

fn analyze_fn(toks: &[Token], name: &str, body_start: usize, body_end: usize) -> FnInfo {
    let mut info = FnInfo {
        name: name.to_string(),
        edges: Vec::new(),
        sockets: Vec::new(),
        calls: Vec::new(),
        pair_violations: Vec::new(),
        acquired: BTreeSet::new(),
    };
    let mut held: Vec<Held> = Vec::new();
    // `let v = expr.min(…)` / `.max(…)` bindings, the slot-pair evidence
    let mut bindings: BTreeMap<String, &'static str> = BTreeMap::new();
    let mut depth = 0i32;
    let mut stmt_start = body_start + 1;
    let mut i = body_start;
    while i <= body_end && i < toks.len() {
        let t = &toks[i];
        if t.is("{") {
            depth += 1;
            stmt_start = i + 1;
            i += 1;
            continue;
        }
        if t.is("}") {
            depth -= 1;
            held.retain(|h| !matches!(h.until, Until::Depth(d) if d > depth));
            stmt_start = i + 1;
            i += 1;
            continue;
        }
        if t.is(";") {
            held.retain(|h| h.until != Until::Stmt);
            stmt_start = i + 1;
            i += 1;
            continue;
        }
        // drop(guard) releases by name
        if t.is_ident("drop")
            && i + 2 < toks.len()
            && toks[i + 1].is("(")
            && toks[i + 2].kind == Kind::Ident
        {
            let dropped = toks[i + 2].text.clone();
            held.retain(|h| h.name.as_deref() != Some(&dropped));
        }
        if let Some(acq) = acquisition_at(toks, i) {
            let line = t.line;
            if acq.blocking {
                for h in &held {
                    info.edges.push((h.class.clone(), acq.class.clone(), line));
                }
                info.acquired.insert(acq.class.clone());
                if acq.class == "slot" && !name.starts_with("lock") {
                    if let Some(first) = held.iter().find(|h| h.class == "slot") {
                        if !pair_ordered(&first.args, &acq.args, &bindings) {
                            info.pair_violations.push(line);
                        }
                    }
                }
            }
            // binding / liveness classification
            let stmt = &toks[stmt_start..i];
            let is_let = stmt.first().map(|s| s.is_ident("let")).unwrap_or(false);
            let after_is_semi = toks
                .get(acq.end + 1)
                .map(|s| s.is(";"))
                .unwrap_or(true);
            let head_is_branch = stmt
                .first()
                .map(|s| s.is_ident("match") || s.is_ident("if") || s.is_ident("while"))
                .unwrap_or(false);
            let (guard_name, until) = if is_let && after_is_semi {
                let mut nm = stmt.get(1).map(|s| s.text.clone());
                if nm.as_deref() == Some("mut") {
                    nm = stmt.get(2).map(|s| s.text.clone());
                }
                (nm, Until::Depth(depth))
            } else if !is_let && head_is_branch {
                (None, Until::Fn)
            } else {
                (None, Until::Stmt)
            };
            held.push(Held {
                class: acq.class,
                name: guard_name,
                args: acq.args,
                until,
            });
            i = acq.end + 1;
            continue;
        }
        // `let lo = a.min(b);` — ascending-order evidence for slot pairs
        if (t.is_ident("min") || t.is_ident("max")) && i > 0 && toks[i - 1].is(".") {
            let stmt = &toks[stmt_start..i];
            if stmt.first().map(|s| s.is_ident("let")).unwrap_or(false) {
                let mut nm = stmt.get(1).map(|s| s.text.clone());
                if nm.as_deref() == Some("mut") {
                    nm = stmt.get(2).map(|s| s.text.clone());
                }
                if let Some(nm) = nm {
                    bindings.insert(nm, if t.is_ident("min") { "min" } else { "max" });
                }
            }
        }
        if t.kind == Kind::Ident && i + 1 < toks.len() && toks[i + 1].is("(") {
            let classes: Vec<String> = held.iter().map(|h| h.class.clone()).collect();
            if is_socket_method(&t.text) {
                info.sockets.push((t.line, classes));
            } else {
                info.calls.push((t.text.clone(), t.line, classes));
            }
        }
        i += 1;
    }
    info
}

/// Is the second slot provably higher-indexed than the first?
fn pair_ordered(
    a1: &[Token],
    a2: &[Token],
    bindings: &BTreeMap<String, &'static str>,
) -> bool {
    if a1.len() != 1 || a2.len() != 1 {
        return false;
    }
    let (t1, t2) = (&a1[0], &a2[0]);
    if t1.kind == Kind::Num && t2.kind == Kind::Num {
        let p1: Option<u64> = t1.text.replace('_', "").parse().ok();
        let p2: Option<u64> = t2.text.replace('_', "").parse().ok();
        return matches!((p1, p2), (Some(a), Some(b)) if a < b);
    }
    if t1.kind == Kind::Ident && t2.kind == Kind::Ident {
        return bindings.get(&t1.text) == Some(&"min")
            && bindings.get(&t2.text) == Some(&"max");
    }
    false
}

/// Run the lock-order rule over one file.
pub fn check_file(path: &str, src: &str) -> Vec<Finding> {
    let toks = strip_tests(tokenize(src));
    let fns = functions(&toks);
    let infos: Vec<FnInfo> = fns
        .iter()
        .map(|f| analyze_fn(&toks, &f.name, f.body_start, f.body_end))
        .collect();
    let mut by_name: BTreeMap<&str, &FnInfo> = BTreeMap::new();
    for info in &infos {
        by_name.entry(info.name.as_str()).or_insert(info);
    }
    // transitive closure: which fns reach a socket op / acquire which locks
    let mut reaches_socket: BTreeMap<&str, bool> = by_name
        .iter()
        .map(|(n, i)| (*n, !i.sockets.is_empty()))
        .collect();
    let mut lock_closure: BTreeMap<&str, BTreeSet<String>> = by_name
        .iter()
        .map(|(n, i)| (*n, i.acquired.clone()))
        .collect();
    loop {
        let mut changed = false;
        for (name, info) in &by_name {
            for (callee, _, _) in &info.calls {
                if !by_name.contains_key(callee.as_str()) {
                    continue;
                }
                if reaches_socket.get(callee.as_str()) == Some(&true)
                    && reaches_socket.get(name) == Some(&false)
                {
                    reaches_socket.insert(name, true);
                    changed = true;
                }
                let add: Vec<String> = lock_closure
                    .get(callee.as_str())
                    .map(|s| s.iter().cloned().collect())
                    .unwrap_or_default();
                if let Some(own) = lock_closure.get_mut(name) {
                    for c in add {
                        changed |= own.insert(c);
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
    let mut findings = Vec::new();
    let mut edges: BTreeSet<(String, String, u32)> = BTreeSet::new();
    for info in &infos {
        for (a, b, l) in &info.edges {
            if a != b {
                edges.insert((a.clone(), b.clone(), *l));
            }
        }
        for l in &info.pair_violations {
            findings.push(Finding::new(
                "lock-order",
                path,
                *l,
                format!(
                    "second slot lock in fn {} without ascending-order evidence \
                     (use the `let lo = a.min(b); let hi = a.max(b);` pattern)",
                    info.name
                ),
            ));
        }
        for (l, classes) in &info.sockets {
            let bad: Vec<&str> = classes
                .iter()
                .map(|c| c.as_str())
                .filter(|c| !socket_ok(c))
                .collect();
            if !bad.is_empty() {
                findings.push(Finding::new(
                    "lock-order",
                    path,
                    *l,
                    format!(
                        "socket operation in fn {} while holding [{}]",
                        info.name,
                        bad.join(", ")
                    ),
                ));
            }
        }
        for (callee, l, classes) in &info.calls {
            if callee == &info.name || !by_name.contains_key(callee.as_str()) {
                continue;
            }
            if reaches_socket.get(callee.as_str()) == Some(&true) {
                let bad: Vec<&str> = classes
                    .iter()
                    .map(|c| c.as_str())
                    .filter(|c| !socket_ok(c))
                    .collect();
                if !bad.is_empty() {
                    findings.push(Finding::new(
                        "lock-order",
                        path,
                        *l,
                        format!(
                            "call to {callee} (reaches a socket op) in fn {} \
                             while holding [{}]",
                            info.name,
                            bad.join(", ")
                        ),
                    ));
                }
            }
            for class in classes {
                if let Some(acq) = lock_closure.get(callee.as_str()) {
                    for c2 in acq {
                        if class != c2 {
                            edges.insert((class.clone(), c2.clone(), *l));
                        }
                    }
                }
            }
        }
    }
    // the documented order is slots before ctl — never the inverse
    for (a, b, l) in &edges {
        if a == "ctl" && (b == "slot" || b == "slot_all") {
            findings.push(Finding::new(
                "lock-order",
                path,
                *l,
                "slot acquired while ctl is held (documented order: slots, then ctl)",
            ));
        }
    }
    findings.extend(cycle_findings(path, &edges));
    findings
}

fn cycle_findings(path: &str, edges: &BTreeSet<(String, String, u32)>) -> Vec<Finding> {
    let mut graph: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (a, b, _) in edges {
        graph.entry(a).or_default().insert(b);
    }
    let mut findings = Vec::new();
    let mut done: BTreeSet<&str> = BTreeSet::new();
    let nodes: Vec<&str> = graph.keys().copied().collect();
    for start in nodes {
        if done.contains(start) {
            continue;
        }
        // iterative DFS with an explicit path stack
        let mut stack: Vec<(&str, Vec<&str>)> = vec![(start, vec![start])];
        while let Some((node, trail)) = stack.pop() {
            done.insert(node);
            if let Some(nexts) = graph.get(node) {
                for next in nexts {
                    if trail.contains(next) {
                        let mut cycle = trail.clone();
                        cycle.push(next);
                        findings.push(Finding::new(
                            "lock-order",
                            path,
                            0,
                            format!("lock-order cycle: {}", cycle.join(" -> ")),
                        ));
                    } else if !done.contains(next) {
                        let mut t = trail.clone();
                        t.push(next);
                        stack.push((next, t));
                    }
                }
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_min_max_pair_passes() {
        let src = r#"
impl G {
    fn one_exchange(&self, l: usize, j: usize) {
        let lo = l.min(j);
        let hi = l.max(j);
        let g_lo = self.lock_slot(lo);
        let g_hi = self.lock_slot(hi);
    }
}
"#;
        assert!(check_file("x.rs", src).is_empty());
    }

    #[test]
    fn unordered_pair_flagged() {
        let src = r#"
impl G {
    fn bad(&self, a: usize, b: usize) {
        let g1 = self.lock_slot(b);
        let g2 = self.lock_slot(a);
    }
}
"#;
        let f = check_file("x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("ascending-order"));
    }

    #[test]
    fn socket_under_ctl_flagged() {
        let src = r#"
impl G {
    fn bad(&self) {
        let ctl = self.lock_ctl();
        self.transport.exchange_on(&mut s, f);
    }
}
"#;
        let f = check_file("x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("socket operation"));
    }

    #[test]
    fn transient_ctl_projection_passes() {
        let src = r#"
impl G {
    fn ok(&self) {
        let gen = self.lock_ctl().generation;
        self.transport.exchange_on(&mut s, gen);
    }
}
"#;
        assert!(check_file("x.rs", src).is_empty());
    }

    #[test]
    fn dropped_guard_releases() {
        let src = r#"
impl G {
    fn ok(&self) {
        let ctl = self.lock_ctl();
        drop(ctl);
        self.transport.exchange_on(&mut s, f);
    }
}
"#;
        assert!(check_file("x.rs", src).is_empty());
    }

    #[test]
    fn socket_through_call_chain_flagged() {
        let src = r#"
impl G {
    fn probe(&self) -> bool {
        self.stream.peek(&mut [0u8]).is_ok()
    }
    fn bad(&self) {
        let map = self.conns.lock().expect("pool");
        self.probe();
    }
}
"#;
        let f = check_file("x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("reaches a socket op"));
    }

    #[test]
    fn ctl_then_slot_inversion_flagged() {
        let src = r#"
impl G {
    fn bad(&self) {
        let c = self.lock_ctl();
        let s = self.lock_slot(0);
    }
}
"#;
        let f = check_file("x.rs", src);
        assert!(f.iter().any(|x| x.message.contains("documented order")), "{f:?}");
    }

    #[test]
    fn lock_cycle_flagged() {
        let src = r#"
impl G {
    fn ab(&self) {
        let a = self.alpha.lock().expect("a");
        let b = self.beta.lock().expect("b");
    }
    fn ba(&self) {
        let b = self.beta.lock().expect("b");
        let a = self.alpha.lock().expect("a");
    }
}
"#;
        let f = check_file("x.rs", src);
        assert!(f.iter().any(|x| x.message.contains("cycle")), "{f:?}");
    }

    #[test]
    fn scoped_block_releases_before_socket() {
        let src = r#"
impl G {
    fn ok(&self) {
        {
            let ctl = self.lock_ctl();
            ctl.round += 1;
        }
        self.transport.exchange_on(&mut s, f);
    }
}
"#;
        assert!(check_file("x.rs", src).is_empty());
    }
}
