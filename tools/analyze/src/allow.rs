//! The allow-list file: `tools/analyze/allowlist.txt`.
//!
//! One entry per line: `<rule> <path-prefix> # <reason>`. The reason is
//! mandatory by convention (reviewed like code); blank lines and `#`
//! comment lines are skipped. A path entry matches itself and, when it
//! ends with `/`, everything under it.

#[derive(Debug, Default)]
pub struct Allowlist {
    entries: Vec<(String, String)>,
}

impl Allowlist {
    pub fn parse(text: &str) -> Self {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let body = line.split('#').next().unwrap_or("").trim();
            let mut parts = body.split_whitespace();
            if let (Some(rule), Some(path)) = (parts.next(), parts.next()) {
                entries.push((rule.to_string(), path.to_string()));
            }
        }
        Allowlist { entries }
    }

    pub fn allows(&self, rule: &str, path: &str) -> bool {
        self.entries.iter().any(|(r, p)| {
            r == rule && (path == p || (p.ends_with('/') && path.starts_with(p.as_str())))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_and_exact_matching() {
        let a = Allowlist::parse(
            "# comment\n\
             ambient-time rust/src/util/ # bench timing\n\
             collections rust/src/service/transport.rs # pool keyed by addr\n",
        );
        assert!(a.allows("ambient-time", "rust/src/util/bench.rs"));
        assert!(a.allows("collections", "rust/src/service/transport.rs"));
        assert!(!a.allows("ambient-time", "rust/src/sim/net.rs"));
        assert!(!a.allows("collections", "rust/src/service/transport2.rs"));
    }
}
