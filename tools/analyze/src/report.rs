//! Findings and the machine-readable report.

use std::fmt;

/// One rule violation, anchored to a file/line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule slug (`lock-order`, `ambient-time`, `collections`,
    /// `unsafe`, `lock-unwrap`, `counter-underflow`, `spec-sync`).
    pub rule: String,
    /// Path relative to the repo root.
    pub path: String,
    /// 1-based line, or 0 for file/tree-level findings.
    pub line: u32,
    pub message: String,
}

impl Finding {
    pub fn new(rule: &str, path: &str, line: u32, message: impl Into<String>) -> Self {
        Finding {
            rule: rule.to_string(),
            path: path.to_string(),
            line,
            message: message.into(),
        }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path, self.line, self.rule, self.message
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render findings as a JSON report:
/// `{"findings": […], "count": N, "ok": bool}`.
pub fn to_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\n  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
            json_escape(&f.rule),
            json_escape(&f.path),
            f.line,
            json_escape(&f.message)
        ));
    }
    if !findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str(&format!(
        "],\n  \"count\": {},\n  \"ok\": {}\n}}\n",
        findings.len(),
        findings.is_empty()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_shape() {
        let fs = vec![Finding::new("lock-order", "a/b.rs", 7, "bad \"stuff\"")];
        let j = to_json(&fs);
        assert!(j.contains("\"rule\": \"lock-order\""));
        assert!(j.contains("\\\"stuff\\\""));
        assert!(j.contains("\"count\": 1"));
        assert!(j.contains("\"ok\": false"));
    }

    #[test]
    fn empty_report_is_ok() {
        let j = to_json(&[]);
        assert!(j.contains("\"count\": 0"));
        assert!(j.contains("\"ok\": true"));
    }
}
