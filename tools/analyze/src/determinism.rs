//! Determinism lint: no ambient time, no hash-ordered collections in
//! determinism-scoped paths.
//!
//! The simulator's byte-identical-trace contract (PR 7) and the wire
//! codec both depend on iteration order being a function of the data,
//! never of `RandomState` or the wall clock. Two rules:
//!
//! * `ambient-time` — `Instant::now()` / `SystemTime::now()` anywhere
//!   outside `service/clock.rs` (the `Clock` abstraction) and `obs/`
//!   (wall-clock timestamps are the point there), unless the file is
//!   allow-listed with a reason in `tools/analyze/allowlist.txt`.
//! * `collections` — `HashMap` / `HashSet` inside the determinism
//!   scope (`sim/`, `sketch/`, `graph/`, `service/membership.rs`,
//!   `service/gossip_loop.rs`, `obs/trace.rs`): wire-encoded or
//!   trace-emitting state is BTreeMap/BTreeSet only.

use crate::allow::Allowlist;
use crate::lexer::{strip_tests, tokenize, Kind};
use crate::report::Finding;

/// Files where ambient time is part of the design, not a leak.
const TIME_BUILTIN_ALLOW: &[&str] = &["rust/src/service/clock.rs", "rust/src/obs/"];

/// The BTreeMap-only scope: wire-encoded or trace-emitting state.
const COLLECTIONS_SCOPE: &[&str] = &[
    "rust/src/sim/",
    "rust/src/sketch/",
    "rust/src/graph/",
    "rust/src/service/membership.rs",
    "rust/src/service/gossip_loop.rs",
    "rust/src/obs/trace.rs",
];

fn in_scope(path: &str, scope: &[&str]) -> bool {
    scope.iter().any(|p| path.starts_with(p))
}

pub fn check_file(path: &str, src: &str, allow: &Allowlist) -> Vec<Finding> {
    let toks = strip_tests(tokenize(src));
    let mut findings = Vec::new();
    let time_allowed =
        in_scope(path, TIME_BUILTIN_ALLOW) || allow.allows("ambient-time", path);
    let collections_checked =
        in_scope(path, COLLECTIONS_SCOPE) && !allow.allows("collections", path);
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        if !time_allowed
            && (t.text == "Instant" || t.text == "SystemTime")
            && i + 3 < toks.len()
            && toks[i + 1].is(":")
            && toks[i + 2].is(":")
            && toks[i + 3].is_ident("now")
        {
            findings.push(Finding::new(
                "ambient-time",
                path,
                t.line,
                format!(
                    "{}::now() outside the Clock abstraction — inject time \
                     via service::clock or allow-list with a reason",
                    t.text
                ),
            ));
        }
        if collections_checked && (t.text == "HashMap" || t.text == "HashSet") {
            findings.push(Finding::new(
                "collections",
                path,
                t.line,
                format!(
                    "{} in a determinism-scoped path — wire-encoded and \
                     trace-emitting state is BTreeMap/BTreeSet only",
                    t.text
                ),
            ));
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_allow() -> Allowlist {
        Allowlist::parse("")
    }

    #[test]
    fn instant_now_in_sim_flagged() {
        let f = check_file(
            "rust/src/sim/net.rs",
            "fn f() { let t = Instant::now(); }",
            &empty_allow(),
        );
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "ambient-time");
    }

    #[test]
    fn clock_module_is_exempt() {
        let f = check_file(
            "rust/src/service/clock.rs",
            "fn f() { let t = Instant::now(); }",
            &empty_allow(),
        );
        assert!(f.is_empty());
    }

    #[test]
    fn allowlist_suppresses_time() {
        let allow = Allowlist::parse(
            "ambient-time rust/src/service/transport.rs # pool idle stamps",
        );
        let f = check_file(
            "rust/src/service/transport.rs",
            "fn f() { let t = Instant::now(); }",
            &allow,
        );
        assert!(f.is_empty());
    }

    #[test]
    fn hashmap_in_codec_flagged() {
        let f = check_file(
            "rust/src/sketch/codec.rs",
            "use std::collections::HashMap;\nfn f() { let m: HashMap<u8, u8> = HashMap::new(); }",
            &empty_allow(),
        );
        assert_eq!(f.len(), 3, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "collections"));
    }

    #[test]
    fn hashmap_outside_scope_ignored() {
        let f = check_file(
            "rust/src/service/transport.rs",
            "use std::collections::HashMap;",
            &empty_allow(),
        );
        assert!(f.is_empty());
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests { fn f() { let t = Instant::now(); } }";
        let f = check_file("rust/src/sim/net.rs", src, &empty_allow());
        assert!(f.is_empty());
    }
}
