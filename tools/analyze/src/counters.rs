//! Counter-underflow audit.
//!
//! Monotonic registry counters (`Counter::get()`) are sampled as
//! baselines and diffed later (`PoolStats`, round reports). A plain
//! `-` between two samples wraps to ~2^64 the moment anything resets or
//! races, and the wrapped value then poisons derived gauges. The repo
//! convention is a `delta_since`-style helper built on
//! `saturating_sub`; this rule flags `… .get() - …` (and `.load(…) -`)
//! subtractions anywhere else.

use crate::lexer::{enclosing_fn, functions, strip_tests, tokenize};
use crate::report::Finding;

/// Helper functions whose whole point is counter differencing; they
/// must (and do) saturate internally.
const DELTA_HELPERS: &[&str] = &["delta_since"];

pub fn check_file(path: &str, src: &str) -> Vec<Finding> {
    let toks = strip_tests(tokenize(src));
    let fns = functions(&toks);
    let mut findings = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        // `.get() -` and `.load(…) -`, excluding `->`
        let reader_len = if t.is_ident("get")
            && i > 0
            && toks[i - 1].is(".")
            && i + 2 < toks.len()
            && toks[i + 1].is("(")
            && toks[i + 2].is(")")
        {
            Some(3usize)
        } else if t.is_ident("load") && i > 0 && toks[i - 1].is(".") && i + 1 < toks.len() && toks[i + 1].is("(") {
            let close = crate::lexer::matching(&toks, i + 1, "(", ")");
            Some(close - i + 1)
        } else {
            None
        };
        let Some(len) = reader_len else { continue };
        let minus = i + len;
        if minus >= toks.len() || !toks[minus].is("-") {
            continue;
        }
        // `->` is a return-type arrow, not a subtraction
        if minus + 1 < toks.len() && toks[minus + 1].is(">") {
            continue;
        }
        let fn_name = enclosing_fn(&fns, i).unwrap_or("?");
        if DELTA_HELPERS.contains(&fn_name) {
            continue;
        }
        findings.push(Finding::new(
            "counter-underflow",
            path,
            t.line,
            format!(
                "unchecked subtraction on a monotonic counter read in fn \
                 {fn_name} — use saturating_sub (or a delta_since-style helper)"
            ),
        ));
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_get_subtraction_flagged() {
        let src = "fn report(&self) { let d = g.exchanges.get() - base; }";
        let f = check_file("x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "counter-underflow");
    }

    #[test]
    fn saturating_sub_passes() {
        let src = "fn report(&self) { let d = g.exchanges.get().saturating_sub(base); }";
        assert!(check_file("x.rs", src).is_empty());
    }

    #[test]
    fn delta_since_helper_exempt() {
        let src = "fn delta_since(&self, base: &Self) -> u64 { self.n.get() - base.n }";
        assert!(check_file("x.rs", src).is_empty());
    }

    #[test]
    fn atomic_load_subtraction_flagged() {
        let src = "fn f(&self) { let d = self.n.load(Ordering::Relaxed) - base; }";
        let f = check_file("x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn arrow_is_not_subtraction() {
        let src = "impl A { fn get(&self) -> u64 { 1 } }";
        assert!(check_file("x.rs", src).is_empty());
    }
}
