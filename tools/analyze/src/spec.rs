//! Wire-spec cross-checker (`spec-sync`).
//!
//! Replaces the regex heart of the old `scripts/check_protocol_sync.sh`
//! with real parsing of both sides:
//!
//! * `sketch/codec.rs` — `ExchangeKind` discriminants, the
//!   `RejectReason` `code()`/`from_code()` pair (checked for bijection),
//!   and `const VERSION`;
//! * `service/membership.rs` — the `MemberStatus` wire codes;
//! * `service/gossip_loop.rs` — the `RestartCause` diagnostic
//!   discriminants (PR 9);
//! * `config.rs` — the canonical `ServiceConfig::set` /
//!   `GossipLoopConfig::set` keys (first literal of each match arm);
//! * `docs/PROTOCOL.md` — the kind/reason/status/cause tables, the
//!   protocol version line, and the configuration-key table;
//! * `README.md` + `docs/PROTOCOL.md` prose — every backticked
//!   `gossip_*` mention must name a real config key.
//!
//! Every comparison runs both directions: code without spec is as much
//! drift as spec without code.

use crate::lexer::{matching, tokenize, Kind, Token};
use crate::report::Finding;
use std::collections::BTreeMap;

/// The six documents the checker cross-references.
pub struct SpecInputs {
    pub codec: String,
    pub membership: String,
    pub gossip_loop: String,
    pub config: String,
    pub protocol_md: String,
    pub readme_md: String,
}

/// `enum <name> { Variant = N, … }` discriminants.
fn enum_discriminants(toks: &[Token], name: &str) -> BTreeMap<String, u64> {
    let mut out = BTreeMap::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident(name) && i > 0 && toks[i - 1].is_ident("enum")) {
            continue;
        }
        let mut j = i + 1;
        while j < toks.len() && !toks[j].is("{") {
            j += 1;
        }
        if j >= toks.len() {
            break;
        }
        let end = matching(toks, j, "{", "}");
        let mut depth = 0i32;
        let mut k = j;
        while k <= end {
            if toks[k].is("{") {
                depth += 1;
            } else if toks[k].is("}") {
                depth -= 1;
            } else if depth == 1
                && toks[k].kind == Kind::Ident
                && k + 2 < toks.len()
                && toks[k + 1].is("=")
                && toks[k + 2].kind == Kind::Num
            {
                if let Ok(v) = toks[k + 2].text.replace('_', "").parse() {
                    out.insert(toks[k].text.clone(), v);
                }
                k += 3;
                continue;
            }
            k += 1;
        }
        break;
    }
    out
}

/// `Type::Variant => N` arms (the `code()` direction).
fn variant_to_code(toks: &[Token], ty: &str) -> BTreeMap<String, u64> {
    let mut out = BTreeMap::new();
    for i in 0..toks.len() {
        if toks[i].is_ident(ty)
            && i + 6 < toks.len()
            && toks[i + 1].is(":")
            && toks[i + 2].is(":")
            && toks[i + 3].kind == Kind::Ident
            && toks[i + 4].is("=")
            && toks[i + 5].is(">")
            && toks[i + 6].kind == Kind::Num
        {
            if let Ok(v) = toks[i + 6].text.replace('_', "").parse() {
                out.entry(toks[i + 3].text.clone()).or_insert(v);
            }
        }
    }
    out
}

/// `N => Type::Variant` and `N => Some(Type::Variant)` arms (the
/// `from_code` direction).
fn code_to_variant(toks: &[Token], ty: &str) -> BTreeMap<u64, String> {
    let mut out = BTreeMap::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident(ty)
            && i + 3 < toks.len()
            && toks[i + 1].is(":")
            && toks[i + 2].is(":")
            && toks[i + 3].kind == Kind::Ident)
        {
            continue;
        }
        // walk back over an optional `Some(` / `Ok(` wrapper
        let mut j = i as isize - 1;
        if j >= 1 && toks[j as usize].is("(") {
            let wrap = &toks[(j - 1) as usize];
            if wrap.is_ident("Some") || wrap.is_ident("Ok") {
                j -= 2;
            }
        }
        if j >= 2
            && toks[j as usize].is(">")
            && toks[(j - 1) as usize].is("=")
            && toks[(j - 2) as usize].kind == Kind::Num
        {
            if let Ok(v) = toks[(j - 2) as usize].text.replace('_', "").parse() {
                out.entry(v).or_insert(toks[i + 3].text.clone());
            }
        }
    }
    out
}

fn const_u64(toks: &[Token], name: &str) -> Option<u64> {
    for i in 0..toks.len() {
        if toks[i].is_ident(name) && i > 0 && toks[i - 1].is_ident("const") {
            for j in i..toks.len().min(i + 10) {
                if toks[j].is("=") && j + 1 < toks.len() && toks[j + 1].kind == Kind::Num {
                    return toks[j + 1].text.replace('_', "").parse().ok();
                }
            }
        }
    }
    None
}

/// The canonical key of each arm of `match key { … }` inside
/// `impl <ty> { fn set … }`: the first string literal of the pattern.
/// Guarded arms (`_ if key.starts_with(…)`) are skipped.
fn config_keys(toks: &[Token], ty: &str) -> Vec<String> {
    let mut keys = Vec::new();
    let Some((impl_start, impl_end)) = impl_span(toks, ty) else {
        return keys;
    };
    let mut i = impl_start;
    while i < impl_end {
        if toks[i].is_ident("match")
            && i + 2 < toks.len()
            && toks[i + 1].is_ident("key")
            && toks[i + 2].is("{")
        {
            let end = matching(toks, i + 2, "{", "}");
            let mut depth = 0i32;
            let mut pattern: Vec<String> = Vec::new();
            let mut guarded = false;
            let mut k = i + 2;
            while k <= end {
                let t = &toks[k];
                if t.is("{") {
                    depth += 1;
                } else if t.is("}") {
                    depth -= 1;
                } else if depth == 1 {
                    if t.kind == Kind::Str {
                        pattern.push(t.text.clone());
                    } else if t.is_ident("if") {
                        guarded = true;
                    } else if t.is("=") && k + 1 <= end && toks[k + 1].is(">") {
                        if !guarded {
                            if let Some(first) = pattern.first() {
                                keys.push(first.clone());
                            }
                        }
                        pattern.clear();
                        guarded = false;
                        k += 2;
                        continue;
                    } else if t.is(",") {
                        // arm-body terminator: drop any literals a
                        // braceless body contributed
                        pattern.clear();
                    }
                }
                k += 1;
            }
            i = end + 1;
            continue;
        }
        i += 1;
    }
    keys
}

/// Every string literal in the `match key` arms (canonical + aliases).
fn config_keys_with_aliases(toks: &[Token], ty: &str) -> Vec<String> {
    let mut keys = Vec::new();
    let Some((impl_start, impl_end)) = impl_span(toks, ty) else {
        return keys;
    };
    let mut i = impl_start;
    while i < impl_end {
        if toks[i].is_ident("match")
            && i + 2 < toks.len()
            && toks[i + 1].is_ident("key")
            && toks[i + 2].is("{")
        {
            let end = matching(toks, i + 2, "{", "}");
            let mut depth = 0i32;
            let mut k = i + 2;
            let mut in_body = false;
            while k <= end {
                let t = &toks[k];
                if t.is("{") {
                    depth += 1;
                } else if t.is("}") {
                    depth -= 1;
                    if depth == 1 {
                        // a braced arm body just closed (no comma follows)
                        in_body = false;
                    }
                } else if depth == 1 {
                    if t.is("=") && k + 1 <= end && toks[k + 1].is(">") {
                        in_body = true;
                        k += 2;
                        continue;
                    }
                    if t.is(",") {
                        in_body = false;
                    }
                    if !in_body && t.kind == Kind::Str {
                        keys.push(t.text.clone());
                    }
                }
                k += 1;
            }
            i = end + 1;
            continue;
        }
        i += 1;
    }
    keys
}

/// Span of `impl <name> { … }` (not `impl Trait for <name>`).
fn impl_span(toks: &[Token], name: &str) -> Option<(usize, usize)> {
    for i in 0..toks.len() {
        if toks[i].is_ident("impl")
            && i + 2 < toks.len()
            && toks[i + 1].is_ident(name)
            && toks[i + 2].is("{")
        {
            return Some((i + 2, matching(toks, i + 2, "{", "}")));
        }
    }
    None
}

/// Rows of the first markdown table whose first two header cells are
/// `h0` and `h1` (case-insensitive): (backticked-name, numeric-value).
fn md_code_table(md: &str, h0: &str, h1: &str) -> Vec<(String, u64)> {
    let mut rows = Vec::new();
    let mut grab = false;
    for line in md.lines() {
        let line = line.trim();
        if !line.starts_with('|') {
            if grab {
                break;
            }
            continue;
        }
        let cells: Vec<&str> = line
            .trim_matches('|')
            .split('|')
            .map(str::trim)
            .collect();
        if cells.len() >= 2
            && cells[0].eq_ignore_ascii_case(h0)
            && cells[1].eq_ignore_ascii_case(h1)
        {
            grab = true;
            continue;
        }
        if !grab {
            continue;
        }
        if cells
            .first()
            .map(|c| c.chars().all(|ch| "-: ".contains(ch)))
            .unwrap_or(true)
        {
            continue;
        }
        if let (Some(name), Some(value)) = (
            backticked(cells[0]),
            cells.get(1).and_then(|c| c.parse::<u64>().ok()),
        ) {
            rows.push((name, value));
        }
    }
    rows
}

/// Backticked names from the first cell of the table headed `key | …`.
fn md_key_table(md: &str) -> Vec<String> {
    let mut keys = Vec::new();
    let mut grab = false;
    for line in md.lines() {
        let line = line.trim();
        if !line.starts_with('|') {
            if grab {
                break;
            }
            continue;
        }
        let cells: Vec<&str> = line
            .trim_matches('|')
            .split('|')
            .map(str::trim)
            .collect();
        if cells
            .first()
            .map(|c| c.eq_ignore_ascii_case("key"))
            .unwrap_or(false)
        {
            grab = true;
            continue;
        }
        if !grab {
            continue;
        }
        if cells
            .first()
            .map(|c| c.chars().all(|ch| "-: ".contains(ch)))
            .unwrap_or(true)
        {
            continue;
        }
        if let Some(name) = backticked(cells[0]) {
            keys.push(name);
        }
    }
    keys
}

fn backticked(cell: &str) -> Option<String> {
    let start = cell.find('`')? + 1;
    let end = start + cell[start..].find('`')?;
    Some(cell[start..end].to_string())
}

/// The `**N**` protocol version stated in PROTOCOL.md.
fn md_version(md: &str) -> Option<u64> {
    for line in md.lines() {
        let lower = line.to_ascii_lowercase();
        if !lower.contains("protocol version") {
            continue;
        }
        let start = line.find("**")? + 2;
        let end = start + line[start..].find("**")?;
        return line[start..end].trim().parse().ok();
    }
    None
}

/// Backticked `gossip_*` identifiers mentioned anywhere in `md`.
fn gossip_mentions(md: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = md;
    while let Some(open) = rest.find('`') {
        let after = &rest[open + 1..];
        let Some(close) = after.find('`') else { break };
        let inner = &after[..close];
        if inner.starts_with("gossip_")
            && inner
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            out.push(inner.to_string());
        }
        rest = &after[close + 1..];
    }
    out.sort();
    out.dedup();
    out
}

fn diff_maps(
    findings: &mut Vec<Finding>,
    what: &str,
    code_path: &str,
    code: &BTreeMap<String, u64>,
    doc: &BTreeMap<String, u64>,
) {
    for (name, value) in code {
        match doc.get(name) {
            None => findings.push(Finding::new(
                "spec-sync",
                "docs/PROTOCOL.md",
                0,
                format!("{what} `{name}` (= {value}) is implemented but missing from the spec table"),
            )),
            Some(dv) if dv != value => findings.push(Finding::new(
                "spec-sync",
                "docs/PROTOCOL.md",
                0,
                format!("{what} `{name}`: code says {value}, spec table says {dv}"),
            )),
            _ => {}
        }
    }
    for name in doc.keys() {
        if !code.contains_key(name) {
            findings.push(Finding::new(
                "spec-sync",
                code_path,
                0,
                format!("{what} `{name}` is in the spec table but not implemented"),
            ));
        }
    }
}

pub fn check(inputs: &SpecInputs) -> Vec<Finding> {
    let mut findings = Vec::new();
    let codec = tokenize(&inputs.codec);
    let membership = tokenize(&inputs.membership);
    let config = tokenize(&inputs.config);

    // 1. ExchangeKind ↔ kind table
    let kinds = enum_discriminants(&codec, "ExchangeKind");
    if kinds.is_empty() {
        findings.push(Finding::new(
            "spec-sync",
            "rust/src/sketch/codec.rs",
            0,
            "could not extract ExchangeKind discriminants",
        ));
    }
    let doc_kinds: BTreeMap<String, u64> =
        md_code_table(&inputs.protocol_md, "kind", "value").into_iter().collect();
    diff_maps(
        &mut findings,
        "frame kind",
        "rust/src/sketch/codec.rs",
        &kinds,
        &doc_kinds,
    );

    // 2. RejectReason: code()/from_code() bijection, then ↔ reason table
    let to_code = variant_to_code(&codec, "RejectReason");
    let from_code = code_to_variant(&codec, "RejectReason");
    for (name, v) in &to_code {
        if from_code.get(v) != Some(name) {
            findings.push(Finding::new(
                "spec-sync",
                "rust/src/sketch/codec.rs",
                0,
                format!(
                    "RejectReason::{name} encodes to {v} but from_code({v}) \
                     does not decode back to it"
                ),
            ));
        }
    }
    for (v, name) in &from_code {
        if !to_code.contains_key(name) {
            findings.push(Finding::new(
                "spec-sync",
                "rust/src/sketch/codec.rs",
                0,
                format!("from_code({v}) yields RejectReason::{name}, which code() never emits"),
            ));
        }
    }
    let doc_reasons: BTreeMap<String, u64> =
        md_code_table(&inputs.protocol_md, "reason", "code").into_iter().collect();
    diff_maps(
        &mut findings,
        "reject reason",
        "rust/src/sketch/codec.rs",
        &to_code,
        &doc_reasons,
    );

    // 3. MemberStatus ↔ status table
    let status_to = variant_to_code(&membership, "MemberStatus");
    let status_from = code_to_variant(&membership, "MemberStatus");
    for (name, v) in &status_to {
        if status_from.get(v) != Some(name) {
            findings.push(Finding::new(
                "spec-sync",
                "rust/src/service/membership.rs",
                0,
                format!(
                    "MemberStatus::{name} encodes to {v} but from_code({v}) \
                     does not decode back to it"
                ),
            ));
        }
    }
    let doc_statuses: BTreeMap<String, u64> =
        md_code_table(&inputs.protocol_md, "status", "code").into_iter().collect();
    diff_maps(
        &mut findings,
        "member status",
        "rust/src/service/membership.rs",
        &status_to,
        &doc_statuses,
    );

    // 3b. RestartCause ↔ the §10.4 cause table (PR 9): the restart
    // diagnostic codes are stable identifiers, kept in lockstep with
    // the spec exactly like the wire enums.
    let gossip_loop = tokenize(&inputs.gossip_loop);
    let causes = enum_discriminants(&gossip_loop, "RestartCause");
    if causes.is_empty() {
        findings.push(Finding::new(
            "spec-sync",
            "rust/src/service/gossip_loop.rs",
            0,
            "could not extract RestartCause discriminants",
        ));
    }
    let doc_causes: BTreeMap<String, u64> =
        md_code_table(&inputs.protocol_md, "cause", "value").into_iter().collect();
    diff_maps(
        &mut findings,
        "restart cause",
        "rust/src/service/gossip_loop.rs",
        &causes,
        &doc_causes,
    );

    // 4. VERSION ↔ "Protocol version: **N**"
    match (const_u64(&codec, "VERSION"), md_version(&inputs.protocol_md)) {
        (Some(c), Some(d)) if c != d => findings.push(Finding::new(
            "spec-sync",
            "docs/PROTOCOL.md",
            0,
            format!("codec VERSION is {c} but the spec states protocol version {d}"),
        )),
        (None, _) => findings.push(Finding::new(
            "spec-sync",
            "rust/src/sketch/codec.rs",
            0,
            "could not extract const VERSION",
        )),
        (_, None) => findings.push(Finding::new(
            "spec-sync",
            "docs/PROTOCOL.md",
            0,
            "could not find the `Protocol version: **N**` statement",
        )),
        _ => {}
    }

    // 5. Config keys ↔ the configuration-key table
    let mut implemented: Vec<String> = config_keys(&config, "ServiceConfig");
    implemented.extend(
        config_keys(&config, "GossipLoopConfig")
            .into_iter()
            .map(|k| format!("gossip_{k}")),
    );
    if implemented.is_empty() {
        findings.push(Finding::new(
            "spec-sync",
            "rust/src/config.rs",
            0,
            "could not extract any ServiceConfig/GossipLoopConfig keys",
        ));
    }
    let documented = md_key_table(&inputs.protocol_md);
    for key in &implemented {
        if !documented.contains(key) {
            findings.push(Finding::new(
                "spec-sync",
                "docs/PROTOCOL.md",
                0,
                format!("config key `{key}` is implemented but missing from the key table"),
            ));
        }
    }
    for key in &documented {
        if !implemented.contains(key) {
            findings.push(Finding::new(
                "spec-sync",
                "rust/src/config.rs",
                0,
                format!("config key `{key}` is documented but not implemented"),
            ));
        }
    }

    // 6. Prose `gossip_*` mentions must name real keys (canonical or alias)
    let mut known: Vec<String> = config_keys_with_aliases(&config, "GossipLoopConfig")
        .into_iter()
        .map(|k| format!("gossip_{k}"))
        .collect();
    known.push("gossip_".to_string()); // the CLI prefix itself
    for (doc, md) in [
        ("docs/PROTOCOL.md", &inputs.protocol_md),
        ("README.md", &inputs.readme_md),
    ] {
        for mention in gossip_mentions(md) {
            if !known.contains(&mention) {
                findings.push(Finding::new(
                    "spec-sync",
                    doc,
                    0,
                    format!("`{mention}` is mentioned but is not a gossip config key"),
                ));
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codec_src() -> String {
        r#"
const VERSION: u8 = 1;
pub enum ExchangeKind { Push = 1, Reply = 2 }
impl RejectReason {
    fn code(self) -> u8 {
        match self { RejectReason::Busy => 1, RejectReason::Malformed => 4 }
    }
    fn from_code(code: u8) -> Result<Self, CodecError> {
        Ok(match code { 1 => RejectReason::Busy, 4 => RejectReason::Malformed,
            other => return Err(err(other)) })
    }
}
"#
        .to_string()
    }

    fn membership_src() -> String {
        r#"
impl MemberStatus {
    pub fn code(self) -> u8 {
        match self { MemberStatus::Alive => 0, MemberStatus::Dead => 2 }
    }
    pub fn from_code(code: u8) -> Option<Self> {
        match code { 0 => Some(MemberStatus::Alive), 2 => Some(MemberStatus::Dead), _ => None }
    }
}
"#
        .to_string()
    }

    fn gossip_loop_src() -> String {
        r#"
#[repr(u8)]
pub enum RestartCause {
    EpochAdvance = 1,
    ViewChange = 2,
}
"#
        .to_string()
    }

    fn config_src() -> String {
        r#"
impl ServiceConfig {
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "alpha" => self.alpha = value.parse()?,
            "max_buckets" | "buckets" => self.max_buckets = value.parse()?,
            _ if key.starts_with("gossip_") => self.gossip.set(&key[7..], value)?,
            other => return Err(format!("unknown key '{other}'")),
        }
        Ok(())
    }
}
impl GossipLoopConfig {
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "fan_out" | "fanout" => self.fan_out = value.parse()?,
            other => return Err(format!("unknown key '{other}'")),
        }
        Ok(())
    }
}
"#
        .to_string()
    }

    fn protocol_md() -> String {
        r#"
Protocol version: **1**.

| kind | value | direction |
|---|---|---|
| `Push` | 1 | a |
| `Reply` | 2 | b |

| reason | code | meaning |
|---|---|---|
| `Busy` | 1 | x |
| `Malformed` | 4 | y |

| status | code | meaning |
|---|---|---|
| `Alive` | 0 | x |
| `Dead` | 2 | y |

| cause | value | meaning |
|---|---|---|
| `EpochAdvance` | 1 | x |
| `ViewChange` | 2 | y |

| key | meaning |
|---|---|
| `alpha` | sketch accuracy |
| `max_buckets` | collapse bound |
| `gossip_fan_out` | partners per round |
"#
        .to_string()
    }

    fn inputs() -> SpecInputs {
        SpecInputs {
            codec: codec_src(),
            membership: membership_src(),
            gossip_loop: gossip_loop_src(),
            config: config_src(),
            protocol_md: protocol_md(),
            readme_md: "uses `gossip_fan_out` for fanout".to_string(),
        }
    }

    #[test]
    fn in_sync_spec_passes() {
        let f = check(&inputs());
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn drifted_kind_value_flagged() {
        let mut inp = inputs();
        inp.codec = inp.codec.replace("Reply = 2", "Reply = 9");
        let f = check(&inp);
        assert!(
            f.iter().any(|x| x.message.contains("code says 9")),
            "{f:?}"
        );
    }

    #[test]
    fn missing_doc_row_flagged() {
        let mut inp = inputs();
        inp.protocol_md = inp.protocol_md.replace("| `Reply` | 2 | b |\n", "");
        let f = check(&inp);
        assert!(
            f.iter().any(|x| x.message.contains("missing from the spec table")),
            "{f:?}"
        );
    }

    #[test]
    fn from_code_asymmetry_flagged() {
        let mut inp = inputs();
        inp.codec = inp.codec.replace("1 => RejectReason::Busy,", "");
        let f = check(&inp);
        assert!(
            f.iter().any(|x| x.message.contains("does not decode back")),
            "{f:?}"
        );
    }

    #[test]
    fn undocumented_config_key_flagged() {
        let mut inp = inputs();
        inp.protocol_md = inp.protocol_md.replace("| `alpha` | sketch accuracy |\n", "");
        let f = check(&inp);
        assert!(
            f.iter()
                .any(|x| x.message.contains("`alpha` is implemented but missing")),
            "{f:?}"
        );
    }

    #[test]
    fn phantom_doc_key_flagged() {
        let mut inp = inputs();
        inp.protocol_md = inp
            .protocol_md
            .replace("| `alpha` | sketch accuracy |", "| `alpha` | x |\n| `betamax` | y |");
        let f = check(&inp);
        assert!(
            f.iter()
                .any(|x| x.message.contains("`betamax` is documented but not implemented")),
            "{f:?}"
        );
    }

    #[test]
    fn stale_gossip_mention_flagged() {
        let mut inp = inputs();
        inp.readme_md = "tune `gossip_retired_knob` for speed".to_string();
        let f = check(&inp);
        assert!(
            f.iter().any(|x| x.message.contains("gossip_retired_knob")),
            "{f:?}"
        );
    }

    #[test]
    fn restart_cause_drift_flagged() {
        let mut inp = inputs();
        inp.gossip_loop = inp.gossip_loop.replace("ViewChange = 2", "ViewChange = 7");
        let f = check(&inp);
        assert!(
            f.iter()
                .any(|x| x.message.contains("restart cause `ViewChange`")),
            "{f:?}"
        );

        // A cause present in code but missing from the spec table.
        let mut inp = inputs();
        inp.protocol_md = inp.protocol_md.replace("| `ViewChange` | 2 | y |\n", "");
        let f = check(&inp);
        assert!(
            f.iter().any(|x| {
                x.message
                    .contains("restart cause `ViewChange` (= 2) is implemented but missing")
            }),
            "{f:?}"
        );
    }

    #[test]
    fn version_drift_flagged() {
        let mut inp = inputs();
        inp.codec = inp.codec.replace("VERSION: u8 = 1", "VERSION: u8 = 2");
        let f = check(&inp);
        assert!(
            f.iter().any(|x| x.message.contains("VERSION is 2")),
            "{f:?}"
        );
    }

    #[test]
    fn alias_keys_need_no_doc_row() {
        // `buckets` and `fanout` are aliases; only canonical keys are
        // required in the table.
        let f = check(&inputs());
        assert!(f.is_empty(), "{f:?}");
    }
}
