//! Fixture-driven integration tests: every rule must accept its pass
//! fixture, flag its fail fixture, and — the self-check — report the
//! real `rust/src/` tree as clean.

use dudd_analyze::allow::Allowlist;
use dudd_analyze::{counters, determinism, locks, metrics, report, spec, unsafe_audit};
use dudd_analyze::{run_rules, RULES};
use std::path::Path;

fn no_allow() -> Allowlist {
    Allowlist::parse("")
}

// ---- lock-order ----

#[test]
fn lock_clean_fixture_passes() {
    let f = locks::check_file("fixture.rs", include_str!("fixtures/lock_clean.rs"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn lock_cycle_fixture_flagged() {
    let f = locks::check_file("fixture.rs", include_str!("fixtures/lock_cycle.rs"));
    assert!(
        f.iter().any(|x| x.message.contains("lock-order cycle")),
        "{f:?}"
    );
}

#[test]
fn socket_under_ctl_fixture_flagged() {
    let f = locks::check_file(
        "fixture.rs",
        include_str!("fixtures/lock_socket_under_ctl.rs"),
    );
    assert!(
        f.iter()
            .any(|x| x.message.contains("socket operation") && x.message.contains("ctl")),
        "{f:?}"
    );
    assert!(
        f.iter().any(|x| x.message.contains("reaches a socket op")),
        "{f:?}"
    );
}

#[test]
fn slot_pair_misorder_fixture_flagged() {
    let f = locks::check_file(
        "fixture.rs",
        include_str!("fixtures/lock_pair_misorder.rs"),
    );
    assert!(
        f.iter().any(|x| x.message.contains("ascending-order")),
        "{f:?}"
    );
    assert!(
        f.iter().any(|x| x.message.contains("documented order")),
        "{f:?}"
    );
}

// ---- determinism ----

#[test]
fn ambient_time_fixture_flagged_outside_clock() {
    let src = include_str!("fixtures/det_ambient_time.rs");
    let f = determinism::check_file("rust/src/sim/fixture.rs", src, &no_allow());
    assert_eq!(f.len(), 2, "{f:?}");
    assert!(f.iter().all(|x| x.rule == "ambient-time"));
}

#[test]
fn ambient_time_fixture_allowed_in_clock_module() {
    let src = include_str!("fixtures/det_ambient_time.rs");
    let f = determinism::check_file("rust/src/service/clock.rs", src, &no_allow());
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn hashmap_fixture_flagged_in_wire_scope() {
    let src = include_str!("fixtures/det_hashmap_wire.rs");
    let f = determinism::check_file("rust/src/sketch/fixture.rs", src, &no_allow());
    assert!(!f.is_empty());
    assert!(f.iter().all(|x| x.rule == "collections"), "{f:?}");
}

#[test]
fn hashmap_fixture_ignored_outside_scope() {
    let src = include_str!("fixtures/det_hashmap_wire.rs");
    let f = determinism::check_file("rust/src/runtime/fixture.rs", src, &no_allow());
    assert!(f.is_empty(), "{f:?}");
}

// ---- unsafe / lock-unwrap ----

#[test]
fn unsafe_fixture_flagged_outside_swap() {
    let src = include_str!("fixtures/unsafe_outside_swap.rs");
    let f = unsafe_audit::check_file("rust/src/graph/fixture.rs", src);
    assert!(f.iter().any(|x| x.rule == "unsafe"), "{f:?}");
}

#[test]
fn unsafe_fixture_allowed_in_swap() {
    let src = include_str!("fixtures/unsafe_outside_swap.rs");
    let f = unsafe_audit::check_file("rust/src/service/swap.rs", src);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn adhoc_lock_unwrap_fixture_flagged() {
    let src = include_str!("fixtures/lock_unwrap_adhoc.rs");
    let f = unsafe_audit::check_file("rust/src/obs/fixture.rs", src);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "lock-unwrap");
    assert!(f[0].message.contains("refresh"));
}

// ---- counter-underflow ----

#[test]
fn counter_fixture_flags_raw_subtractions_only() {
    let src = include_str!("fixtures/counter_underflow.rs");
    let f = counters::check_file("rust/src/obs/fixture.rs", src);
    assert_eq!(f.len(), 2, "{f:?}");
    assert!(f.iter().all(|x| x.rule == "counter-underflow"));
}

// ---- spec-sync ----

fn fixture_spec(protocol_md: &str) -> spec::SpecInputs {
    spec::SpecInputs {
        codec: include_str!("fixtures/spec_codec.rs").to_string(),
        membership: include_str!("fixtures/spec_membership.rs").to_string(),
        gossip_loop: include_str!("fixtures/spec_gossip_loop.rs").to_string(),
        config: include_str!("fixtures/spec_config.rs").to_string(),
        protocol_md: protocol_md.to_string(),
        readme_md: "Pass `gossip_fan_out` (alias `gossip_fanout`) on the CLI.".to_string(),
    }
}

#[test]
fn spec_fixture_in_sync_passes() {
    let f = spec::check(&fixture_spec(include_str!("fixtures/spec_protocol.md")));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn spec_fixture_drift_flagged() {
    let f = spec::check(&fixture_spec(include_str!(
        "fixtures/spec_protocol_drift.md"
    )));
    // seeded drift 1: PushReply value disagrees
    assert!(
        f.iter()
            .any(|x| x.message.contains("PushReply") && x.message.contains("spec table says 9")),
        "{f:?}"
    );
    // seeded drift 2: phantom config key
    assert!(
        f.iter()
            .any(|x| x.message.contains("`gossip_retry_budget` is documented but not implemented")),
        "{f:?}"
    );
    // seeded drift 3: stale prose mention
    assert!(
        f.iter()
            .any(|x| x.message.contains("gossip_fanout_bias")),
        "{f:?}"
    );
    // seeded drift 4: restart cause implemented but undocumented
    assert!(
        f.iter().any(|x| {
            x.message
                .contains("restart cause `GenerationCatchUp` (= 3) is implemented but missing")
        }),
        "{f:?}"
    );
}

// ---- metrics-sync ----

fn fixture_metrics(md: &str) -> Vec<dudd_analyze::report::Finding> {
    let sources = vec![(
        "rust/src/obs/fixture.rs".to_string(),
        include_str!("fixtures/metrics_obs.rs").to_string(),
    )];
    metrics::check(&sources, md)
}

#[test]
fn metrics_fixture_in_sync_passes() {
    let f = fixture_metrics(include_str!("fixtures/metrics_catalog.md"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn metrics_fixture_drift_flagged_both_directions() {
    let f = fixture_metrics(include_str!("fixtures/metrics_catalog_drift.md"));
    assert_eq!(f.len(), 2, "{f:?}");
    // seeded drift 1: referenced in code, no catalogue row
    assert!(
        f.iter().any(|x| {
            x.path == "rust/src/obs/fixture.rs"
                && x.message.contains("`dudd_drift` is referenced in code")
        }),
        "{f:?}"
    );
    // seeded drift 2: catalogue row, no code reference
    assert!(
        f.iter().any(|x| {
            x.path == "docs/OBSERVABILITY.md"
                && x.message.contains("`dudd_ghost_total` is in the catalogue")
        }),
        "{f:?}"
    );
}

// ---- report shape ----

#[test]
fn json_report_is_stable_shape() {
    let f = locks::check_file("fixture.rs", include_str!("fixtures/lock_cycle.rs"));
    let j = report::to_json(&f);
    assert!(j.contains("\"ok\": false"));
    assert!(j.contains("\"rule\": \"lock-order\""));
}

// ---- self-check: the real tree is clean ----

#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = run_rules(RULES, &root).expect("walk rust/src");
    assert!(
        findings.is_empty(),
        "rules fired on the real tree:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
