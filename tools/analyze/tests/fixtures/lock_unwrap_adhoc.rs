// FAIL fixture: ad-hoc poisoning policy. The lock_state helper is the
// accepted home for .lock().expect(); the inline one in refresh is not.
#![forbid(unsafe_code)]

impl Cache {
    fn lock_state(&self) -> MutexGuard<'_, State> {
        self.state.lock().expect("cache state poisoned")
    }

    fn refresh(&self) {
        let mut state = self.state.lock().unwrap();
        state.generation += 1;
    }
}
