// Spec fixture: a miniature codec in the same shape as
// rust/src/sketch/codec.rs.
pub const VERSION: u8 = 3;

#[derive(Clone, Copy)]
pub enum ExchangeKind {
    Push = 1,
    PushReply = 2,
    Probe = 7,
}

impl RejectReason {
    pub fn code(self) -> u8 {
        match self {
            RejectReason::Busy => 1,
            RejectReason::Malformed => 4,
        }
    }

    pub fn from_code(code: u8) -> Result<Self, CodecError> {
        Ok(match code {
            1 => RejectReason::Busy,
            4 => RejectReason::Malformed,
            other => return Err(CodecError::BadReason(other)),
        })
    }
}
