// FAIL fixture (when presented under any path other than
// rust/src/service/swap.rs): unsafe outside the pinned module.
#![forbid(unsafe_code)]

fn read_unchecked(xs: &[u64], i: usize) -> u64 {
    unsafe { *xs.get_unchecked(i) }
}
