// FAIL fixture: raw subtraction between monotonic counter samples. The
// delta_since helper and the saturating form must both pass.
#![forbid(unsafe_code)]

impl PoolStats {
    fn delta_since(&self, base: &Self) -> u64 {
        self.hits.get() - base.hits
    }

    fn report(&self, base: u64) -> u64 {
        let ok = self.hits.get().saturating_sub(base);
        let bad = self.misses.get() - base;
        ok + bad
    }

    fn atomic_report(&self, base: u64) -> u64 {
        self.inflight.load(Ordering::Relaxed) - base
    }
}
