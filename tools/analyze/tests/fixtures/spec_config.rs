// Spec fixture: config key dispatch in the same shape as
// rust/src/config.rs.
impl ServiceConfig {
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "alpha" => self.alpha = value.parse().map_err(|_| bad(key))?,
            "max_buckets" | "buckets" => self.max_buckets = value.parse().map_err(|_| bad(key))?,
            _ if key.starts_with("gossip_") => self.gossip.set(&key["gossip_".len()..], value)?,
            other => return Err(format!("unknown config key '{other}'")),
        }
        Ok(())
    }
}

impl GossipLoopConfig {
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "fan_out" | "fanout" => self.fan_out = value.parse().map_err(|_| bad(key))?,
            "round_interval_ms" => self.round_interval_ms = value.parse().map_err(|_| bad(key))?,
            other => return Err(format!("unknown gossip key '{other}'")),
        }
        Ok(())
    }
}
