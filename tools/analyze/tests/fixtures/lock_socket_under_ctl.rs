// FAIL fixture: blocking socket I/O while holding the control-plane
// lock, both directly and through an intra-file call chain.
impl Gossip {
    fn direct(&self) {
        let ctl = self.lock_ctl();
        self.transport.exchange_on(&mut stream, ctl.generation);
    }

    fn probe(&self) -> bool {
        self.stream.peek(&mut [0u8]).is_ok()
    }

    fn via_call(&self) {
        let conns = self.conns.lock().expect("pool");
        self.probe();
    }
}
