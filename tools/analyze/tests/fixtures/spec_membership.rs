// Spec fixture: MemberStatus wire codes in the same shape as
// rust/src/service/membership.rs.
impl MemberStatus {
    pub fn code(self) -> u8 {
        match self {
            MemberStatus::Alive => 0,
            MemberStatus::Suspect => 1,
            MemberStatus::Dead => 2,
        }
    }

    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(MemberStatus::Alive),
            1 => Some(MemberStatus::Suspect),
            2 => Some(MemberStatus::Dead),
            _ => None,
        }
    }
}
