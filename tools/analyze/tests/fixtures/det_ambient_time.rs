// FAIL fixture (when presented under a non-exempt path): ambient time
// read outside the Clock abstraction. The #[cfg(test)] module at the
// bottom must NOT be flagged.
fn pace_round(&self) {
    let started = Instant::now();
    let stamp = SystemTime::now();
    self.trace.push(started, stamp);
}

#[cfg(test)]
mod tests {
    #[test]
    fn timing_in_tests_is_fine() {
        let _t = Instant::now();
    }
}
