// PASS fixture: the idioms the lock-order rule must accept.
impl Gossip {
    fn one_exchange(&self, local: usize, peer: usize) {
        let lo = local.min(peer);
        let hi = local.max(peer);
        let g_lo = self.lock_slot(lo);
        let g_hi = self.lock_slot(hi);
        merge(g_lo, g_hi);
    }

    fn round(&self) {
        let _gate = self.lock_gate();
        let generation = self.lock_ctl().generation;
        self.transport.exchange_on(&mut stream, generation);
    }

    fn scoped(&self) {
        {
            let mut ctl = self.lock_ctl();
            ctl.round += 1;
        }
        self.transport.exchange_on(&mut stream, 0);
    }

    fn explicit_drop(&self) {
        let ctl = self.lock_ctl();
        let gen = ctl.generation;
        drop(ctl);
        self.transport.exchange_membership(&mut stream, gen);
    }
}
