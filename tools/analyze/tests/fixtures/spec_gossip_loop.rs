//! Fixture gossip-loop source for the spec-sync tests: a `RestartCause`
//! enum with stable diagnostic discriminants, as in the real
//! `rust/src/service/gossip_loop.rs`.

/// Why a refresh restarted the protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum RestartCause {
    EpochAdvance = 1,
    ViewChange = 2,
    GenerationCatchUp = 3,
}
