//! `metrics-sync` code-side fixture: a miniature obs layer exercising
//! every reference shape the rule must understand — plain registrations,
//! labelled registrations, a labelled-bundle helper whose family name
//! arrives as a parameter at the call site, a scraper-style read with a
//! label selector baked into the literal, and a test-only family that
//! must never reach the catalogue.

pub fn register(r: &Registry) -> Result<()> {
    r.counter("dudd_rounds_total", "Gossip rounds executed.")?;
    r.gauge("dudd_drift", "Largest relative probe drift.")?;
    r.histogram_with(
        "dudd_round_phase_seconds",
        "Wall clock per gossip-round phase.",
        &[("phase", "exchange")],
    )?;
    RestartCounters::register(r, "dudd_restarts_total", "Protocol restarts by cause.")?;
    Ok(())
}

pub fn read_rtt(m: &Exposition) -> f64 {
    m.get("dudd_exchange_rtt_seconds{quantile=\"0.99\"}")
        .expect("dudd_* families are statically valid")
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_only_families_are_ignored() {
        let _ = "dudd_test_only_total";
    }
}
