// FAIL fixture: a second slot acquired with no ascending-order
// evidence, plus an explicit ctl-then-slot inversion.
impl Gossip {
    fn unordered_pair(&self, a: usize, b: usize) {
        let g1 = self.lock_slot(b);
        let g2 = self.lock_slot(a);
        merge(g1, g2);
    }

    fn inverted(&self) {
        let ctl = self.lock_ctl();
        let slot = self.lock_slot(0);
        slot.absorb(ctl.pending);
    }
}
