// FAIL fixture: two functions acquire the same two mutexes in opposite
// orders — a classic AB/BA deadlock the per-file lock graph must reject.
impl Registry {
    fn publish(&self) {
        let families = self.families.lock().expect("families");
        let ring = self.ring.lock().expect("ring");
        families.push(ring.snapshot());
    }

    fn render(&self) {
        let ring = self.ring.lock().expect("ring");
        let families = self.families.lock().expect("families");
        ring.extend(families.iter());
    }
}
