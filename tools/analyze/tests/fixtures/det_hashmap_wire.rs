// FAIL fixture (when presented under a determinism-scoped path such as
// rust/src/sketch/): hash-ordered state in a wire-encoding path.
use std::collections::HashMap;

fn encode_buckets(buckets: &HashMap<i32, u64>, out: &mut Vec<u8>) {
    for (k, v) in buckets {
        out.extend_from_slice(&k.to_be_bytes());
        out.extend_from_slice(&v.to_be_bytes());
    }
}
