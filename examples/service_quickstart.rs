//! Service quickstart: sharded concurrent ingest, epoch snapshots,
//! sliding windows, and fronting a gossip peer — in two minutes.
//!
//! ```bash
//! cargo run --release --example service_quickstart
//! ```

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::{GossipLoopConfig, ServiceConfig};
use duddsketch::gossip::PeerState;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::service::{GossipLoop, GossipMember, QuantileService, ServicePeer};
use duddsketch::sketch::UddSketch;
use duddsketch::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    // 1. Start a service: 4 ingest shards, 0.1% relative error.
    let mut cfg = ServiceConfig::default();
    cfg.shards = 4;
    cfg.batch_size = 4096;
    let svc = QuantileService::start(cfg)?;
    println!("service up: {} shards", svc.shard_count());

    // 2. Ingest one million heavy-tailed latencies from 4 concurrent
    //    producers — each gets its own batching writer, no shared state.
    let mut rng = default_rng(7);
    let data: Vec<f64> = (0..1_000_000)
        .map(|_| 10f64.powf(rng.next_f64() * 5.0 - 1.0))
        .collect();
    let sw = Stopwatch::start();
    std::thread::scope(|scope| {
        for part in data.chunks(data.len() / 4 + 1) {
            let mut w = svc.writer();
            scope.spawn(move || {
                w.insert_batch(part);
                w.flush();
            });
        }
    });
    let snap = svc.flush();
    println!(
        "ingested {} values in {:.0} ms -> epoch {}, {} buckets, alpha {:.5}",
        snap.count(),
        sw.millis(),
        snap.epoch(),
        snap.bucket_count(),
        snap.alpha()
    );

    // 3. Queries hit the published snapshot — lock-free, never blocking
    //    ingest — and answer exactly like one sequential sketch fed the
    //    same stream (mergeability, Definition 7).
    let mut seq: UddSketch = UddSketch::new(0.001, 1024).map_err(anyhow::Error::msg)?;
    seq.extend(&data);
    println!("\n  q      service         sequential");
    for q in [0.01, 0.5, 0.99] {
        let a = snap.quantile(q).map_err(anyhow::Error::msg)?;
        let b = seq.quantile(q).map_err(anyhow::Error::msg)?;
        println!("  {q:<5}  {a:<14.6e}  {b:<14.6e}");
        assert_eq!(a, b, "snapshot must equal the sequential sketch");
    }

    // 4. Turnstile deletes ride the same sharded path.
    let mut w = svc.writer();
    for &x in &data[..100_000] {
        w.delete(x);
    }
    w.flush();
    drop(w);
    let snap = svc.flush();
    println!(
        "\nafter deleting the first 100k: count = {} (epoch {})",
        snap.count(),
        snap.epoch()
    );

    // 5. The live snapshot can front a gossip peer (Algorithm 3's local
    //    sketch, maintained by the service instead of replayed).
    let peer = ServicePeer::new(0, &svc);
    let other = PeerState::init(1, &data[..50_000], 0.001, 1024).map_err(anyhow::Error::msg)?;
    let mut mine = peer.into_state();
    let mut theirs = other;
    PeerState::exchange(&mut mine, &mut theirs).map_err(anyhow::Error::msg)?;
    println!(
        "gossip exchange done: peer estimates global p99 = {:.6e}",
        mine.query(0.99).map_err(anyhow::Error::msg)?
    );

    svc.shutdown();
    println!("service shut down cleanly");

    // 6. Or let the continuous gossip loop do all of that: a fleet of
    //    services (here: one live service + two simulated peers) keeps a
    //    network-converged global view published next to each local
    //    snapshot — refresh → exchange → serve, every round.
    let mut cfg = ServiceConfig::default();
    cfg.shards = 2;
    let svc = QuantileService::start_shared(cfg)?;
    let mut w = svc.writer();
    w.insert_batch(&(1..=4000).map(f64::from).collect::<Vec<_>>());
    w.flush();
    svc.flush();
    let members = vec![
        GossipMember::service(svc.clone()),
        GossipMember::from_dataset(&(4001..=8000).map(f64::from).collect::<Vec<_>>(), 0.001, 1024)?,
        GossipMember::from_dataset(&(8001..=12000).map(f64::from).collect::<Vec<_>>(), 0.001, 1024)?,
    ];
    let gl = GossipLoop::start(GossipLoopConfig::default(), members)?;
    let mut rounds = 0;
    while !gl.step().converged && rounds < 100 {
        rounds += 1;
    }
    let view = gl.view();
    println!(
        "\ngossip loop: {} rounds -> fleet size {}, union length {}, global p50 = {:.6e}",
        view.round(),
        view.estimated_peers(),
        view.estimated_total(),
        view.query(0.5).map_err(anyhow::Error::msg)?
    );
    gl.shutdown();
    Ok(())
}
