//! Service quickstart on the `prelude` surface: build a node fluently,
//! ingest concurrently, query through `QuantileReader`, gossip with a
//! fleet — and stand up a two-node loopback-TCP fleet — in two minutes.
//!
//! ```bash
//! cargo run --release --example service_quickstart
//! ```

use duddsketch::prelude::*;
use duddsketch::rng::{default_rng, Rng};
use duddsketch::util::Stopwatch;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // 1. Build a node: every knob is a named method, validated (with the
    //    key named) before anything spawns. 4 ingest shards, 0.1% error.
    let node = Node::builder().alpha(0.001).shards(4).batch_size(4096).build()?;
    println!("node up: {} shards", node.service().shard_count());

    // 2. Ingest one million heavy-tailed latencies from 4 concurrent
    //    producers — each gets its own batching writer, no shared state.
    let mut rng = default_rng(7);
    let data: Vec<f64> = (0..1_000_000)
        .map(|_| 10f64.powf(rng.next_f64() * 5.0 - 1.0))
        .collect();
    let sw = Stopwatch::start();
    std::thread::scope(|scope| {
        for part in data.chunks(data.len() / 4 + 1) {
            let mut w = node.writer();
            scope.spawn(move || {
                w.insert_batch(part);
                w.flush();
            });
        }
    });
    let snap = node.flush();
    println!(
        "ingested {} values in {:.0} ms -> epoch {}, {} buckets, alpha {:.5}",
        snap.count(),
        sw.millis(),
        snap.epoch(),
        snap.bucket_count(),
        snap.alpha()
    );

    // 3. Queries hit the published snapshot — lock-free, never blocking
    //    ingest — and answer exactly like one sequential sketch fed the
    //    same stream (mergeability, Definition 7). `QuantileReader` is
    //    the one interface over both surfaces, so verification code is
    //    written once.
    let mut seq: UddSketch = UddSketch::new(0.001, 1024).map_err(anyhow::Error::msg)?;
    seq.extend(&data);
    fn report(name: &str, reader: &dyn QuantileReader, qs: &[f64]) -> Vec<f64> {
        let ests = reader.quantiles(qs).expect("non-empty reader");
        println!("  {name:<10} n={:<9} p50={:.6e} p99={:.6e}", reader.count(), ests[0], ests[1]);
        ests
    }
    println!("\n  surface    count     p50           p99");
    let a = report("snapshot", snap.as_ref(), &[0.5, 0.99]);
    let b = report("sequential", &seq, &[0.5, 0.99]);
    assert_eq!(a, b, "snapshot must equal the sequential sketch");

    // 4. Turnstile deletes ride the same sharded path.
    let mut w = node.writer();
    for &x in &data[..100_000] {
        w.delete(x);
    }
    w.flush();
    drop(w);
    let snap = node.flush();
    println!(
        "\nafter deleting the first 100k: count = {} (epoch {})",
        snap.count(),
        snap.epoch()
    );
    node.shutdown();

    // 5. A gossiping node: the builder wires the fleet and the loop in
    //    one expression. Here: one live service + two simulated peers on
    //    the in-process transport (the default).
    let node = Node::builder()
        .alpha(0.001)
        .shards(2)
        .peer(GossipMember::from_dataset(
            &(4001..=8000).map(f64::from).collect::<Vec<_>>(),
            0.001,
            1024,
        )?)
        .peer(GossipMember::from_dataset(
            &(8001..=12000).map(f64::from).collect::<Vec<_>>(),
            0.001,
            1024,
        )?)
        .build()?;
    let mut w = node.writer();
    w.insert_batch(&(1..=4000).map(f64::from).collect::<Vec<_>>());
    w.flush();
    drop(w);
    node.flush();
    let mut rounds = 0;
    while !node.step().expect("gossip enabled").converged && rounds < 100 {
        rounds += 1;
    }
    let view = node.global_view().expect("gossip enabled");
    println!(
        "\ngossip loop: {} rounds -> fleet size {}, union length {}, global p50 = {:.6e}",
        view.round(),
        view.estimated_peers(),
        view.estimated_total(),
        view.query(0.5).map_err(anyhow::Error::msg)?
    );
    node.shutdown();

    // 6. The same loop over real sockets: bind each node's TcpTransport
    //    first (the address book must exist before any loop starts),
    //    then list every other node as a remote peer — member order is
    //    global, `self_index` marks this node's slot. Exchanges ship
    //    length-prefixed codec frames; failures and timeouts cancel the
    //    exchange with both sides keeping their pre-round state (§7.2).
    let deadline = Duration::from_millis(500);
    let t0 = TcpTransport::bind("127.0.0.1:0", deadline)?;
    let t1 = TcpTransport::bind("127.0.0.1:0", deadline)?;
    let (a0, a1) = (t0.listen_addr().unwrap(), t1.listen_addr().unwrap());
    let node0 = Node::builder()
        .shards(2)
        .exchange_deadline_ms(500)
        .self_index(0)
        .transport(t0)
        .remote_peer(a1)
        .build()?;
    let node1 = Node::builder()
        .shards(2)
        .exchange_deadline_ms(500)
        .self_index(1)
        .transport(t1)
        .remote_peer(a0)
        .build()?;
    for (node, lo, hi) in [(&node0, 1, 5000), (&node1, 5001, 10000)] {
        let mut w = node.writer();
        w.insert_batch(&(lo..=hi).map(f64::from).collect::<Vec<_>>());
        w.flush();
        node.flush();
    }
    for _ in 0..6 {
        node0.step();
        node1.step();
    }
    let v = node1.global_view().expect("gossip enabled");
    println!(
        "tcp fleet: node1 sees {} peers, union length {}, global p50 = {:.6e}",
        v.estimated_peers(),
        v.estimated_total(),
        v.query(0.5).map_err(anyhow::Error::msg)?
    );
    node0.shutdown();
    node1.shutdown();
    println!("fleet shut down cleanly");
    Ok(())
}
