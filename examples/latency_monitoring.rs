//! The paper's §1 motivating scenario: monitor web-request latency
//! percentiles (p95/p98/p99) across a fleet of servers *without* a central
//! collector — each host sketches its own latencies and the fleet gossips
//! to consensus.
//!
//! Latency distributions are classically heavy-tailed (log-normal body +
//! Pareto tail); relative value error is the right guarantee here: a p99
//! of 870 ms estimated as 871 ms is fine, as 1240 ms is not — regardless
//! of how many requests sit between them (the rank-error view).
//!
//! ```bash
//! cargo run --release --example latency_monitoring
//! ```

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::ExperimentConfig;
use duddsketch::data::DatasetKind;
use duddsketch::gossip::Protocol;
use duddsketch::graph::paper_ba;
use duddsketch::metrics::relative_error;
use duddsketch::rng::{default_rng, Normal, Rng, Sample, ShiftedPareto};
use duddsketch::sketch::UddSketch;

/// Synthesize one host's request latencies (ms): log-normal body with an
/// occasional Pareto tail (slow backend / GC pause), per-host load factor.
fn host_latencies(host: usize, n: usize, master: &duddsketch::rng::Xoshiro256pp) -> Vec<f64> {
    let mut rng = master.derive(0x1A7E + host as u64);
    let load = 0.8 + 0.4 * rng.next_f64(); // per-host speed factor
    let body = Normal::new(3.4, 0.5); // ln-space: median ~30 ms
    let tail = ShiftedPareto::new(2.2, 120.0, 250.0); // slow path, >250 ms
    (0..n)
        .map(|_| {
            if rng.chance(0.03) {
                tail.sample(&mut rng) * load
            } else {
                body.sample(&mut rng).exp() * load
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    const HOSTS: usize = 200;
    const REQUESTS_PER_HOST: usize = 20_000;
    let quantiles = [0.5, 0.95, 0.98, 0.99];

    let mut cfg = ExperimentConfig::default();
    cfg.peers = HOSTS;
    cfg.dataset = DatasetKind::Uniform; // placeholder; we supply data below
    cfg.alpha = 0.001;
    cfg.quantiles = quantiles.to_vec();

    let master = default_rng(2026);
    println!("synthesizing {REQUESTS_PER_HOST} request latencies on {HOSTS} hosts...");
    let datasets: Vec<Vec<f64>> = (0..HOSTS)
        .map(|h| host_latencies(h, REQUESTS_PER_HOST, &master))
        .collect();

    // Central reference (what a latency aggregation service would compute
    // if it could see every request).
    let mut central: UddSketch = UddSketch::new(cfg.alpha, cfg.max_buckets)
        .map_err(anyhow::Error::msg)?;
    for d in &datasets {
        central.extend(d);
    }

    // Decentralized: gossip over a Barabási–Albert overlay.
    let mut grng = master.derive(0x6EA4);
    let graph = paper_ba(HOSTS, &mut grng);
    let mut proto = Protocol::new(&cfg, graph, &datasets, &master)?;

    println!("\nround | fleet-wide p99 seen by host 17 | rel.err vs central");
    let central_p99 = central.quantile(0.99).map_err(anyhow::Error::msg)?;
    for round in [1usize, 2, 4, 6, 8, 10, 12, 15] {
        proto.run(round - proto.round());
        let est = proto.states()[17].query(0.99).map_err(anyhow::Error::msg)?;
        println!(
            "  {:>3} | {:>10.2} ms                 | {:.2e}",
            round,
            est,
            relative_error(est, central_p99)
        );
    }

    println!("\nfinal fleet percentiles (any host can answer — asking host 42):");
    println!("  q      distributed     central         rel.err");
    for &q in &quantiles {
        let est = proto.states()[42].query(q).map_err(anyhow::Error::msg)?;
        let tru = central.quantile(q).map_err(anyhow::Error::msg)?;
        println!(
            "  {:<5}  {:>9.2} ms    {:>9.2} ms    {:.2e}",
            q,
            est,
            tru,
            relative_error(est, tru)
        );
    }
    Ok(())
}
