//! Sensor-network quantile summaries under churn — the q-digest
//! motivating domain ([10] in the paper) replayed with DUDDSketch: battery
//! -powered sensors join and leave (Yao churn), yet the surviving network
//! keeps a consensus view of the measurement distribution.
//!
//! ```bash
//! cargo run --release --example sensor_network
//! ```

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::churn::ChurnKind;
use duddsketch::config::ExperimentConfig;
use duddsketch::data::DatasetKind;
use duddsketch::experiments::run_with_snapshots;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.peers = 400; // sensor motes
    cfg.items_per_peer = 1_000; // readings per mote
    cfg.dataset = DatasetKind::Exponential; // inter-event-style readings
    cfg.churn = ChurnKind::YaoPareto; // heterogeneous on/off cycling
    cfg.quantiles = vec![0.05, 0.25, 0.5, 0.75, 0.95];

    println!("sensor field: {}", cfg.summary());
    println!("\ngossip with Yao churn (motes sleep and wake):");
    println!("round | online | ARE(median) | ARE(p95)");

    let out = run_with_snapshots(&cfg, &[2, 5, 10, 15, 20, 30])?;
    for snap in &out.snapshots {
        let med = snap.quantiles.iter().find(|q| q.q == 0.5).unwrap();
        let p95 = snap.quantiles.iter().find(|q| q.q == 0.95).unwrap();
        println!(
            "  {:>3} | {:>5}  | {:>10.3e} | {:>10.3e}",
            snap.rounds, snap.online, med.are, p95.are
        );
    }

    let last = out.snapshots.last().unwrap();
    println!("\nconverged field summary (any online mote answers):");
    for qs in &last.quantiles {
        println!(
            "  q={:<5} -> {:.6e}  (avg rel.err across motes: {:.2e})",
            qs.q, qs.truth, qs.are
        );
    }
    println!(
        "\nnote: truth = the sequential UDDSketch over all {} motes' readings;",
        cfg.peers
    );
    println!("churned motes rejoin with their stale state and re-converge.");
    Ok(())
}
