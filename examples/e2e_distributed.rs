//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): exercises the full three-layer
//! stack on a real small workload and reports the paper's headline metric.
//!
//! Pipeline:
//!   1. generate the paper's four synthetic workloads + the power dataset
//!      (Table 1 / §7.3) across a 1000-peer Barabási–Albert overlay;
//!   2. build per-peer UDDSketch summaries (Layer-3 Rust hot path);
//!   3. run the gossip protocol — natively, and where artifacts are
//!      available also through the AOT-compiled JAX/Pallas `avg_pairs`
//!      artifact on the PJRT CPU client (Layers 1+2, `make artifacts`);
//!   4. answer the Table-2 quantile set from an arbitrary peer and report
//!      relative error vs the sequential algorithm — the paper's headline
//!      "distributed == sequential" claim — plus wall-clock and round
//!      telemetry.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_distributed
//! ```

// Plain-data configs are mutated after `default()` on purpose (see lib.rs).
#![allow(clippy::field_reassign_with_default)]

use duddsketch::config::{ExecutorKind, ExperimentConfig, PAPER_QUANTILES};
use duddsketch::data::{all_peer_datasets, DatasetKind};
use duddsketch::experiments::run_with_snapshots;
use duddsketch::gossip::{PjrtExecutor, Protocol, RoundMode};
use duddsketch::graph::paper_ba;
use duddsketch::metrics::relative_error;
use duddsketch::rng::default_rng;
use duddsketch::sketch::UddSketch;
use duddsketch::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    println!("=== DUDDSketch end-to-end driver ===\n");

    // ---- full protocol over every workload (native executor) ----------
    let mut grand_worst: f64 = 0.0;
    for dataset in [
        DatasetKind::Adversarial,
        DatasetKind::Uniform,
        DatasetKind::Exponential,
        DatasetKind::Normal,
        DatasetKind::Power,
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = dataset;
        cfg.peers = 1000;
        cfg.items_per_peer = 2_000;
        cfg.rounds = 25;
        let sw = Stopwatch::start();
        let out = run_with_snapshots(&cfg, &[5, 10, 15, 20, 25])?;
        let wall = sw.secs();
        print!("{:<12}", dataset.name());
        for snap in &out.snapshots {
            let worst = snap
                .quantiles
                .iter()
                .map(|q| q.are)
                .fold(0.0f64, f64::max);
            print!(" R{:<2}:{:<9.2e}", snap.rounds, worst);
        }
        let final_worst = out
            .snapshots
            .last()
            .unwrap()
            .quantiles
            .iter()
            .map(|q| q.are)
            .fold(0.0f64, f64::max);
        grand_worst = grand_worst.max(final_worst);
        println!("  [{wall:.1}s total]");
    }
    println!(
        "\nheadline: worst ARE across all workloads/quantiles at R=25: {grand_worst:.2e}"
    );
    println!("(paper: relative errors 'go to zero' by 15–25 rounds — Figs. 1–4, 11)");

    // ---- PJRT-accelerated round (Layers 1+2 on the request path) ------
    println!("\n--- PJRT executor (AOT JAX/Pallas artifact) ---");
    match PjrtExecutor::discover(1000) {
        Err(e) => println!("artifacts not available ({e:#}); skipping PJRT leg"),
        Ok(_) => {
            let mut cfg = ExperimentConfig::default();
            cfg.dataset = DatasetKind::Uniform;
            cfg.peers = 1000;
            cfg.items_per_peer = 1_000;
            cfg.executor = ExecutorKind::Pjrt;
            let master = default_rng(cfg.seed);
            let datasets =
                all_peer_datasets(cfg.dataset, cfg.peers, cfg.items_per_peer, &master);
            let mut seq: UddSketch =
                UddSketch::new(cfg.alpha, cfg.max_buckets).map_err(anyhow::Error::msg)?;
            for d in &datasets {
                seq.extend(d);
            }
            let mut grng = master.derive(0x6EA4);
            let graph = paper_ba(cfg.peers, &mut grng);

            let sw = Stopwatch::start();
            let mut proto = Protocol::new(&cfg, graph.clone(), &datasets, &master)?;
            proto.run(60); // matched mode needs more rounds than sequential
            let pjrt_wall = sw.secs();

            let mut cfg_native = cfg.clone();
            cfg_native.executor = ExecutorKind::Native;
            let sw = Stopwatch::start();
            let mut native = Protocol::new(&cfg_native, graph, &datasets, &master)?;
            native.set_mode(RoundMode::Matched);
            native.run(60);
            let native_wall = sw.secs();

            let mut worst: f64 = 0.0;
            for &q in PAPER_QUANTILES.iter() {
                let truth = seq.quantile(q).map_err(anyhow::Error::msg)?;
                let est = proto.states()[123].query(q).map_err(anyhow::Error::msg)?;
                worst = worst.max(relative_error(est, truth));
            }
            println!(
                "pjrt 60 matched rounds: {pjrt_wall:.2}s | native same: {native_wall:.2}s | worst RE vs sequential: {worst:.2e}"
            );
            let h = proto.history().last().unwrap();
            println!(
                "last round: {} exchanges, {} online (P={})",
                h.exchanges, h.online, cfg.peers
            );
        }
    }

    println!("\nE2E driver complete.");
    Ok(())
}
