//! Quickstart: the sequential UDDSketch API in two minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use duddsketch::rng::{default_rng, Rng};
use duddsketch::sketch::{ExactQuantiles, UddSketch};

fn main() -> anyhow::Result<()> {
    // 1. Create a sketch: 0.1% relative value error, at most 1024 buckets.
    let mut sketch: UddSketch = UddSketch::new(0.001, 1024).map_err(anyhow::Error::msg)?;

    // 2. Stream data through it — here one million log-uniform values
    //    spanning five decades, the kind of heavy-tailed input where
    //    rank-error sketches lose relative accuracy.
    let mut rng = default_rng(7);
    let data: Vec<f64> = (0..1_000_000)
        .map(|_| 10f64.powf(rng.next_f64() * 5.0 - 1.0))
        .collect();
    sketch.extend(&data);
    println!(
        "ingested {} values -> {} buckets, {} collapses, alpha = {:.5}",
        data.len(),
        sketch.bucket_count(),
        sketch.collapses(),
        sketch.alpha()
    );

    // 3. Query any quantile; compare against the exact oracle.
    let exact = ExactQuantiles::new(&data);
    println!("\n  q      estimate        exact           rel.err");
    for q in [0.01, 0.25, 0.5, 0.75, 0.95, 0.99, 0.999] {
        let est = sketch.quantile(q).map_err(anyhow::Error::msg)?;
        let tru = exact.quantile(q).map_err(anyhow::Error::msg)?;
        println!(
            "  {:<5}  {:<14.6e}  {:<14.6e}  {:.2e}",
            q,
            est,
            tru,
            (est - tru).abs() / tru
        );
    }

    // 4. Sketches merge losslessly (Definition 7) — the property the whole
    //    distributed protocol rests on.
    let (left, right) = data.split_at(data.len() / 2);
    let mut a: UddSketch = UddSketch::new(0.001, 1024).map_err(anyhow::Error::msg)?;
    let mut b: UddSketch = UddSketch::new(0.001, 1024).map_err(anyhow::Error::msg)?;
    a.extend(left);
    b.extend(right);
    a.merge(&b).map_err(anyhow::Error::msg)?;
    let merged_p99 = a.quantile(0.99).map_err(anyhow::Error::msg)?;
    let direct_p99 = sketch.quantile(0.99).map_err(anyhow::Error::msg)?;
    println!("\nmerge(S(D1), S(D2)) p99 = {merged_p99:.6e} == S(D1 u D2) p99 = {direct_p99:.6e}");
    assert_eq!(merged_p99, direct_p99);

    // 5. Deletions work too (turnstile model).
    let mut t: UddSketch = UddSketch::new(0.01, 256).map_err(anyhow::Error::msg)?;
    for x in [10.0, 20.0, 30.0] {
        t.insert(x);
    }
    t.delete(30.0);
    println!(
        "turnstile: after insert {{10,20,30}} / delete {{30}}: median = {:.3}",
        t.quantile(0.5).map_err(anyhow::Error::msg)?
    );
    Ok(())
}
